"""Process-environment perf preset for launchers (DESIGN.md §11).

The megakernel benchmarks are sensitive to three process-level knobs that
no amount of in-graph work can fix after the interpreter is up:

  * tcmalloc — host allocations (input pipeline, jit bookkeeping) are
    measurably faster under tcmalloc, but LD_PRELOAD only takes effect at
    exec time, so the preset either prints shell exports or re-execs the
    target command.
  * ``--xla_step_marker_location=1`` — puts the step marker at the outer
    while loop (0 = computation entry), so profiles and launch counts
    attribute per-step work to steps, not to the whole program.
  * log suppression (``TF_CPP_MIN_LOG_LEVEL=4``) and the tcmalloc large-
    alloc report threshold — both exist to keep benchmark stdout parseable.

Usage:
    eval "$(python -m repro.launch.env --sh)"         # current shell
    python -m repro.launch.env -- python -m repro.launch.train ...
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, Optional

# candidate tcmalloc shared objects, most specific first (the exact path
# varies by distro; LD_PRELOAD of a missing path breaks every child exec,
# so the preset only sets it when one actually exists)
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
)

# XLA flags the preset guarantees are present (merged with any caller-set
# XLA_FLAGS; caller wins on conflicting values of the same flag)
XLA_PERF_FLAGS = ("--xla_step_marker_location=1",)


def find_tcmalloc() -> Optional[str]:
    for path in TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def _merge_xla_flags(existing: str) -> str:
    have = {f.split("=", 1)[0] for f in existing.split() if f}
    add = [f for f in XLA_PERF_FLAGS if f.split("=", 1)[0] not in have]
    return " ".join(add + existing.split())


def perf_env(base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """The preset as a {name: value} delta over ``base`` (default
    ``os.environ``). Only returns keys whose value should change; never
    clobbers a caller-set XLA flag of the same name."""
    base = dict(os.environ if base is None else base)
    env: Dict[str, str] = {}
    tc = find_tcmalloc()
    if tc is not None:
        preload = base.get("LD_PRELOAD", "")
        if tc not in preload.split(os.pathsep):
            env["LD_PRELOAD"] = (tc + os.pathsep + preload if preload
                                 else tc)
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000")
    if "TF_CPP_MIN_LOG_LEVEL" not in base:
        env["TF_CPP_MIN_LOG_LEVEL"] = "4"
    merged = _merge_xla_flags(base.get("XLA_FLAGS", ""))
    if merged != base.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = merged
    return env


def apply(environ: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Apply the preset in place (default: ``os.environ``) and return the
    delta that was applied. NOTE ``LD_PRELOAD`` and ``XLA_FLAGS`` only
    matter to processes exec'd AFTER this call — apply before importing
    jax, or use the CLI re-exec form."""
    environ = os.environ if environ is None else environ   # type: ignore
    delta = perf_env(dict(environ))
    environ.update(delta)
    return delta


def _sh_quote(s: str) -> str:
    return "'" + s.replace("'", "'\\''") + "'"


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="print (or exec a command under) the perf env preset")
    ap.add_argument("--sh", action="store_true",
                    help="print eval-able `export K=V` lines")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- command to exec with the preset applied")
    args = ap.parse_args(argv)
    cmd = args.cmd[1:] if args.cmd[:1] == ["--"] else args.cmd
    if cmd:
        env = dict(os.environ)
        env.update(perf_env(env))
        os.execvpe(cmd[0], cmd, env)
    delta = perf_env()
    for k in sorted(delta):
        if args.sh:
            print(f"export {k}={_sh_quote(delta[k])}")
        else:
            print(f"{k}={delta[k]}")


if __name__ == "__main__":
    main()
