import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers AND compiles on the production meshes, and extract the
roofline inputs (FLOPs, bytes, collective traffic) from the compiled
artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json
"""
import argparse
import json
import re
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.obs import MetricsRegistry, Tracer, get_tracer, monotonic, \
    set_tracer
from repro.configs import (INPUT_SHAPES, ASSIGNED_ARCHS, applicable_pairs,
                           get_config, shape_applicable)
from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.core.moe import ParallelContext
from repro.launch.mesh import make_production_mesh
from repro.models.model import decode_step, init_cache, init_model, prefill
from repro.parallel.sharding import (batch_specs, cache_specs, param_specs,
                                     state_specs, to_shardings)
from repro.training.steps import init_train_state, make_train_step, total_loss

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for this (arch, shape) as ShapeDtypeStructs."""
    B = shape.global_batch
    L = shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, L), i32), "labels": sds((B, L), i32),
                 "loss_mask": sds((B, L), f32)}
    else:
        batch = {"tokens": sds((B, L), i32)}
    if cfg.vlm is not None:
        batch["img_embeds"] = sds((B, cfg.vlm.n_image_tokens, cfg.vlm.d_image), dt)
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = sds((B, cfg.encdec.encoder_seq, cfg.d_model), dt)
        else:
            batch["enc_tokens"] = sds((B, cfg.encdec.encoder_seq), i32)
    return batch


# ---------------------------------------------------------------------------
# collective parsing
# ---------------------------------------------------------------------------

from repro.analysis import parse_collectives  # noqa: E402

# ---------------------------------------------------------------------------
# lowering per shape kind
# ---------------------------------------------------------------------------

def lower_combo(cfg: ModelConfig, shape: InputShape, mesh, *,
                static_decision=None, tag: str = "",
                tc_overrides=None) -> Dict[str, Any]:
    import dataclasses as dc
    ctx = ParallelContext(mesh=mesh)
    tc = TrainConfig(moment_dtype="bfloat16" if cfg.fsdp else "float32")
    if tc_overrides:
        tc = dc.replace(tc, **tc_overrides)
    key = jax.random.PRNGKey(0)
    tr = get_tracer()
    t0 = monotonic()

    sh = lambda specs: to_shardings(mesh, specs)

    if shape.kind == "train":
        state_shape = jax.eval_shape(
            lambda: init_train_state(init_model(key, cfg), tc))
        st_specs = sh(state_specs(cfg, ctx, state_shape))
        batch = input_specs(cfg, shape)
        b_specs = sh(batch_specs(cfg, ctx, batch))
        step = make_train_step(cfg, tc, ctx, jit=False)

        def fn(state, b):
            return step(state, b, static_decision)

        jitted = jax.jit(fn, in_shardings=(st_specs, b_specs),
                         out_shardings=(st_specs, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_shape, batch)
        tokens = shape.global_batch * shape.seq_len

    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(lambda: init_model(key, cfg))
        p_specs = sh(param_specs(cfg, ctx, params_shape))
        batch = input_specs(cfg, shape)
        b_specs = sh(batch_specs(cfg, ctx, batch))
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        c_specs = sh(cache_specs(cfg, ctx, cache_shape))

        def fn(params, b):
            return prefill(params, b, cfg, ctx, max_seq=shape.seq_len)

        jitted = jax.jit(fn, in_shardings=(p_specs, b_specs),
                         out_shardings=(None, c_specs))
        lowered = jitted.lower(params_shape, batch)
        tokens = shape.global_batch * shape.seq_len

    else:  # decode: ONE new token against a seq_len KV cache
        params_shape = jax.eval_shape(lambda: init_model(key, cfg))
        p_specs = sh(param_specs(cfg, ctx, params_shape))
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        c_specs = sh(cache_specs(cfg, ctx, cache_shape))
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        idx = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(params, caches, token, index):
            return decode_step(params, caches, token, index, cfg, ctx)

        jitted = jax.jit(fn, in_shardings=(p_specs, c_specs, None, None),
                         out_shardings=(None, c_specs),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shape, cache_shape, tok, idx)
        tokens = shape.global_batch

    t_lower = monotonic() - t0
    tr.instant("dryrun.lowered", arch=cfg.arch_id, shape=shape.name,
               kind=shape.kind)
    t0 = monotonic()
    with tr.span("dryrun.compile", arch=cfg.arch_id, shape=shape.name):
        compiled = lowered.compile()
    t_compile = monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # one dict per program pre-jax-0.5
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_d[f] = int(v)

    res = {
        "arch": cfg.arch_id,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(mesh.shape),
        "n_devices": int(mesh.size),
        "tag": tag,
        "tokens_per_step": tokens,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": mem_d,
        "collectives": colls,
        "lower_s": t_lower,
        "compile_s": t_compile,
    }
    return res


def art_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    suff = f"__{tag}" if tag else ""
    return os.path.join(ART_DIR, f"{arch}__{shape}__{mesh_name}{suff}.json")


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            static_decision=None, tag: str = "", verbose: bool = True,
            overrides: Dict[str, Any] = None,
            registry: MetricsRegistry = None):
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod512" if multi_pod else "pod256"
    res = lower_combo(cfg, shape, mesh, static_decision=static_decision,
                      tag=tag)
    if registry is not None:
        registry.counter("dryrun/combos").inc()
        registry.histogram("dryrun/lower_s").observe(res["lower_s"])
        registry.histogram("dryrun/compile_s").observe(res["compile_s"])
    path = art_path(arch, shape_name, mesh_name, tag)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if verbose:
        gb = res["memory"].get("temp_size_in_bytes", 0) / 2**30
        arg = res["memory"].get("argument_size_in_bytes", 0) / 2**30
        a2a = res["collectives"].get("all-to-all", {})
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}{' '+tag if tag else ''}: "
              f"OK  flops/dev={res['flops']:.3g} temp={gb:.2f}GiB arg={arg:.2f}GiB "
              f"a2a={a2a.get('count',0)}ops/{a2a.get('bytes',0)/2**20:.1f}MiB "
              f"(lower {res['lower_s']:.0f}s compile {res['compile_s']:.0f}s)")
    return res


def comm_table(arch: str, shape_name: str, *, multi_pod: bool = False,
               quant: str = "int8", n_chunks: int = 0) -> Dict[str, Any]:
    """Per-substrate predicted wire bytes for (arch x shape) on the
    production mesh — the DESIGN.md §10/§14 what-if table with exposed
    bytes and bandwidth-weighted two-tier time estimates. Pure cost-model
    math (comm/cost.py): nothing is lowered, compiled, or run."""
    from repro.comm import format_table, substrate_table
    cfg = get_config(arch)
    assert cfg.moe is not None, f"{arch} has no MoE layer to dispatch"
    shape = INPUT_SHAPES[shape_name]
    axes = ({"pod": 2, "data": 16, "model": 16} if multi_pod
            else {"data": 16, "model": 16})
    dp = axes["data"] * axes.get("pod", 1)     # batch-sharding axes (§4)
    ep = axes["data"]                          # EP group == data axis
    tokens = (shape.global_batch if shape.kind == "decode"
              else shape.global_batch * shape.seq_len)
    per_shard = max(tokens // dp, 1)
    table = substrate_table(cfg, tokens_per_shard=per_shard, ep=ep,
                            is_training=shape.kind == "train",
                            quant=quant, n_chunks=n_chunks)
    mesh_name = "pod512" if multi_pod else "pod256"
    nc = n_chunks or cfg.moe.comm.n_chunks
    print(f"[comm-table] {arch} x {shape_name} x {mesh_name}: "
          f"{per_shard} tokens/device, ep={ep}, quant={quant}, "
          f"n_chunks={nc} "
          f"(per-device FORWARD bytes per step; train backward doubles)")
    print(format_table(table))
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--comm-table", action="store_true",
                    help="print the per-substrate predicted bytes table "
                         "for --arch x --shape (comm/cost.py; no "
                         "compile, no step)")
    ap.add_argument("--comm-quant", default="int8", choices=["int8", "fp8"],
                    help="wire dtype the --comm-table prices compressed "
                         "substrates at")
    ap.add_argument("--comm-chunks", type=int, default=0,
                    help="capacity micro-chunks the --comm-table prices "
                         "overlapped substrates at (0 = config default)")
    ap.add_argument("--lint-table", action="store_true",
                    help="print the static lint pass x executable matrix "
                         "(analysis/lint.py; pure lowering, nothing is "
                         "executed)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--decision", default=None, choices=[None, "routed", "dropped"],
                    help="bake a static gating-dropout decision (host_cond)")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans: exact cost_analysis "
                         "(XLA counts scan bodies once)")
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="enable the span tracer and write a Chrome-trace/"
                         "Perfetto JSON of lower/compile timing here")
    ap.add_argument("--metrics-out", default=None,
                    help="write lower/compile timing histograms here "
                         "(.prom/.txt = Prometheus text, else JSON)")
    args = ap.parse_args()
    set_tracer(Tracer(enabled=bool(args.trace_out)))
    reg = MetricsRegistry()
    if args.comm_table:
        assert args.arch and args.shape, "--comm-table needs --arch --shape"
        comm_table(args.arch, args.shape, multi_pod=args.multi_pod,
                   quant=args.comm_quant, n_chunks=args.comm_chunks)
        return
    if args.lint_table:
        from repro.analysis.lint import format_lint_table, lint_table
        print(format_lint_table(lint_table()))
        return
    dec = {None: None, "routed": False, "dropped": True}[args.decision]
    overrides = {}
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.no_remat:
        overrides["remat"] = False
    if args.unroll:
        overrides["scan_layers"] = False
    if args.dtype:
        overrides["dtype"] = args.dtype

    if args.all:
        ok, fail = 0, []
        for arch, shp in applicable_pairs():
            try:
                run_one(arch, shp, multi_pod=args.multi_pod,
                        static_decision=dec, tag=args.tag,
                        overrides=overrides, registry=reg)
                ok += 1
            except Exception as e:  # noqa: BLE001
                fail.append((arch, shp, f"{type(e).__name__}: {e}"))
                print(f"[dryrun] {arch} x {shp}: FAIL {type(e).__name__}: "
                      f"{str(e)[:300]}")
        print(f"[dryrun] done: {ok} ok, {len(fail)} failed")
        _dryrun_obs_out(args, reg)
        if fail:
            raise SystemExit(1)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    assert shape_applicable(args.arch, args.shape), \
        f"{args.arch} x {args.shape} marked inapplicable (see DESIGN.md §3)"
    res = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  static_decision=dec, tag=args.tag, overrides=overrides,
                  registry=reg)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives",)}, indent=1))
    print(json.dumps(res["collectives"], indent=1))
    _dryrun_obs_out(args, reg)


def _dryrun_obs_out(args, reg: MetricsRegistry) -> None:
    if args.trace_out:
        get_tracer().export(args.trace_out)
    if args.metrics_out:
        if args.metrics_out.endswith((".prom", ".txt")):
            reg.to_prometheus(args.metrics_out)
        else:
            reg.to_json(args.metrics_out)





# ---------------------------------------------------------------------------
# exact costing by per-layer-type extrapolation
# ---------------------------------------------------------------------------
# XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, not
# x trip-count, so the scan-mode artifacts under-count FLOPs/bytes/
# collectives of deep models. Unrolling the full 61-100 layer models is
# too slow on this container, so instead we lower SMALL unrolled variants
# that preserve the layer-type structure, solve the linear system
#   metric(variant) = base + sum_type count_type(variant) * c_type
# and extrapolate every metric to the full depth. Costs are exactly linear
# in per-type layer counts (params, activations, collectives all scale
# per layer), so this is exact up to XLA fusion boundary effects.

def _variant_cfgs(cfg: ModelConfig):
    import dataclasses as dc
    mk = lambda **kw: dc.replace(cfg, scan_layers=False, **kw)
    if cfg.encdec is not None:
        e = cfg.encdec
        return [mk(n_layers=2, encdec=dc.replace(e, n_encoder_layers=2)),
                mk(n_layers=2, encdec=dc.replace(e, n_encoder_layers=4)),
                mk(n_layers=4, encdec=dc.replace(e, n_encoder_layers=2))]
    if cfg.vlm is not None:
        v = cfg.vlm
        return [mk(n_layers=5),
                mk(n_layers=10),
                mk(n_layers=4, vlm=dc.replace(v, cross_attn_period=2))]
    if cfg.hybrid is not None:
        h = cfg.hybrid
        return [mk(n_layers=4, hybrid=dc.replace(h, global_attn_layers=(0,))),
                mk(n_layers=5, hybrid=dc.replace(h, global_attn_layers=(0,))),
                mk(n_layers=5, hybrid=dc.replace(h, global_attn_layers=(0, 4)))]
    if cfg.moe is not None and cfg.moe.first_dense_layers > 0:
        import dataclasses as dc2
        m1 = dc2.replace(cfg.moe, first_dense_layers=1)
        m2 = dc2.replace(cfg.moe, first_dense_layers=2)
        return [mk(n_layers=2, moe=m1), mk(n_layers=3, moe=m1),
                mk(n_layers=3, moe=m2)]
    if cfg.moe is not None and cfg.moe.moe_layer_period > 1:
        return [mk(n_layers=2), mk(n_layers=4), mk(n_layers=6)]
    return [mk(n_layers=2), mk(n_layers=4)]


def _type_counts(cfg: ModelConfig):
    """{LayerSpec: n_layers} over decoder (+ encoder) plans."""
    from collections import Counter
    from repro.models.transformer import layer_plan
    c = Counter()
    for seg in layer_plan(cfg):
        for spec in seg.pattern:
            c[("dec", spec)] += seg.repeats
    if cfg.encdec is not None:
        for seg in layer_plan(cfg, encoder=True):
            for spec in seg.pattern:
                c[("enc", spec)] += seg.repeats
    return dict(c)


def _extract_metrics(res):
    m = {"flops": res["flops"], "bytes_accessed": res["bytes_accessed"]}
    for kind, rec in res["collectives"].items():
        for f in ("count", "bytes", "wire_bytes"):
            m[f"coll/{kind}/{f}"] = rec[f]
    return m


def exact_costs(arch: str, shape_name: str, *, multi_pod: bool = False,
                verbose: bool = True, overrides=None, tag: str = "exact",
                tc_overrides=None, static_decision=None):
    import dataclasses as dc
    import numpy as np
    cfg = get_config(arch)
    if overrides:
        moe_over = {k[4:]: v for k, v in overrides.items()
                    if k.startswith("moe.")}
        ssm_over = {k[4:]: v for k, v in overrides.items()
                    if k.startswith("ssm.")}
        plain = {k: v for k, v in overrides.items() if "." not in k}
        if moe_over and cfg.moe is not None:
            plain["moe"] = dc.replace(cfg.moe, **moe_over)
        if ssm_over and cfg.ssm is not None:
            plain["ssm"] = dc.replace(cfg.ssm, **ssm_over)
        cfg = dc.replace(cfg, **plain)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod512" if multi_pod else "pod256"
    variants = _variant_cfgs(cfg)
    full_counts = _type_counts(cfg)
    types = sorted(full_counts, key=str)
    rows, metrics_list = [], []
    t0 = monotonic()
    for vc in variants:
        counts = _type_counts(vc)
        assert set(counts) <= set(full_counts), \
            (arch, "variant introduces a layer type absent from full config")
        res = lower_combo(vc, shape, mesh, tag="exactvar",
                          tc_overrides=tc_overrides,
                          static_decision=static_decision)
        rows.append([1.0] + [float(counts.get(t, 0)) for t in types])
        metrics_list.append(_extract_metrics(res))
    a = np.array(rows)
    keys = sorted({k for m in metrics_list for k in m})
    pred = {}
    full_vec = np.array([1.0] + [float(full_counts[t]) for t in types])
    for k in keys:
        y = np.array([m.get(k, 0.0) for m in metrics_list])
        coef, *_ = np.linalg.lstsq(a, y, rcond=None)
        coef = np.maximum(coef, 0.0)     # costs are nonnegative
        pred[k] = float(full_vec @ coef)
    # assemble an artifact shaped like lower_combo's, memory from scan run
    scan_path = art_path(arch, shape_name, mesh_name,
                         "" if tag == "exact" else tag + "mem")
    memory = {}
    if os.path.exists(scan_path):
        with open(scan_path) as f:
            memory = json.load(f).get("memory", {})
    colls = {}
    for k, v in pred.items():
        if k.startswith("coll/"):
            _, kind, field = k.split("/")
            colls.setdefault(kind, {})[field] = v
    res = {
        "arch": cfg.arch_id, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "n_devices": int(mesh.size),
        "tag": tag, "method": "layer-type extrapolation",
        "tokens_per_step": (shape.global_batch * shape.seq_len
                            if shape.kind != "decode" else shape.global_batch),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "flops": pred.get("flops", -1.0),
        "bytes_accessed": pred.get("bytes_accessed", -1.0),
        "memory": memory, "collectives": colls,
        "lower_s": 0.0, "compile_s": monotonic() - t0,
    }
    with open(art_path(arch, shape_name, mesh_name, tag), "w") as f:
        json.dump(res, f, indent=1)
    if verbose:
        a2a = colls.get("all-to-all", {})
        print(f"[{tag}] {arch} x {shape_name} x {mesh_name}: "
              f"flops/dev={res['flops']:.3g} "
              f"a2a={a2a.get('wire_bytes', 0)/2**20:.1f}MiB "
              f"({res['compile_s']:.0f}s, {len(variants)} variants)")
    return res


def exact_main():
    import sys
    ok, fail = 0, []
    only = sys.argv[2] if len(sys.argv) > 2 else None
    for arch, shp in applicable_pairs():
        if only and arch != only:
            continue
        try:
            exact_costs(arch, shp)
            ok += 1
        except Exception as e:  # noqa: BLE001
            fail.append((arch, shp))
            print(f"[exact] {arch} x {shp}: FAIL {type(e).__name__}: "
                  f"{str(e)[:300]}")
    print(f"[exact] done: {ok} ok, {len(fail)} failed: {fail}")


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "--exact-all":
        exact_main()
    else:
        main()
