"""HLO-text collective parsing (roofline inputs). Import-safe: does not
touch jax device state."""
import re
from typing import Dict


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}
_COLLS = ("all-to-all", "all-gather", "all-reduce", "reduce-scatter",
          "collective-permute")
_SHAPE_RE = re.compile(r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\])\s*"
                       r"([a-z\-]+)")
_TUPLE_ELT = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes per collective kind + record group sizes."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            m = _SHAPE_RE.search(s)
            if not m:
                continue
            op = m.group(4)
            if op not in _COLLS:
                continue
            if "-start" in s.split("=")[1][:80]:
                pass
            if m.group(1) is not None:      # tuple result
                bytes_ = sum(_shape_bytes(d, dims)
                             for d, dims in _TUPLE_ELT.findall(m.group(1)))
            else:
                bytes_ = _shape_bytes(m.group(2), m.group(3))
            g = 1
            gi = _GROUPS_IOTA.search(s)
            if gi:
                g = int(gi.group(2))
            else:
                gl = _GROUPS_LIST.search(s)
                if gl:
                    g = len(gl.group(1).split(","))
            rec = out.setdefault(op, {"count": 0, "bytes": 0.0,
                                      "wire_bytes": 0.0, "max_group": 1})
            rec["count"] += 1
            rec["bytes"] += bytes_
            rec["max_group"] = max(rec["max_group"], g)
            # per-device wire traffic (ring algorithms)
            if op == "all-gather":
                wire = bytes_ * (g - 1) / max(g, 1)
            elif op == "all-reduce":
                wire = 2 * bytes_ * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                wire = bytes_ * (g - 1)   # result is the scattered shard
            elif op == "all-to-all":
                wire = bytes_ * (g - 1) / max(g, 1)
            else:                          # collective-permute
                wire = bytes_
            rec["wire_bytes"] += wire
    return out


