"""Batched serving CLI for any arch, via the compiled decoding engine.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 8 --prompt-len 64 --max-new 32
  PYTHONPATH=src python -m repro.launch.serve --arch zcode-m3-base --reduced \
      --beam 4                      # beam search
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --temperature 0.8 --top-k 40  # sampling

Generation runs through ``repro.serve`` (DESIGN.md §7): prefill + the
whole token loop in ONE jitted executable — no per-token Python dispatch.
MoE archs honour ``--backend`` (DESIGN.md §6): oracle / sharded / pallas
execution of the expert layers during prefill+decode.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_model
from repro.serve import GenerateConfig, make_generate_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sampling pool size (0 = full vocab)")
    ap.add_argument("--beam", type=int, default=1,
                    help=">1 = beam search (overrides sampling)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="EOS token id for early exit (-1 = generate "
                         "max-new tokens unconditionally)")
    ap.add_argument("--backend", default=None,
                    choices=[None, "auto", "oracle", "sharded", "pallas"],
                    help="MoE execution backend (DESIGN.md §6)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.backend and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, backend=args.backend))
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(
                key, (args.batch, 32), 3, cfg.vocab)

    gen = GenerateConfig(max_new=args.max_new, temperature=args.temperature,
                         top_k=args.top_k, beam_width=args.beam,
                         eos_id=args.eos)
    fn = make_generate_fn(cfg, gen)
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch, key))   # compile + run
    t_compile = time.time() - t0
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch, key))
    dt = time.time() - t0
    n_tok = int(np.asarray(res.lengths).sum())
    print(f"arch={cfg.arch_id} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new} beam={args.beam}")
    print(f"compile+first: {t_compile:.2f} s; steady: {dt*1e3:.1f} ms "
          f"({dt/max(int(res.steps), 1)*1e3:.2f} ms/step, "
          f"{n_tok/dt:.0f} tok/s)")
    print("sample:", np.asarray(res.tokens)[0][:16].tolist())


if __name__ == "__main__":
    main()
