"""Serving CLI: one-shot batched decode OR a continuous-batching loop.

One-shot (the compiled engine, DESIGN.md §7):

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 8 --prompt-len 64 --max-new 32
  PYTHONPATH=src python -m repro.launch.serve --arch zcode-m3-base --reduced \
      --beam 4                      # beam search
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --temperature 0.8 --top-k 40  # sampling

Continuous batching (slot pool + scheduler, DESIGN.md §9): ``--trace N``
synthesizes N requests with Poisson arrivals (``--rate`` req/s), mixed
prompt lengths and per-request token budgets, serves them through
``repro.serve.ContinuousScheduler``, and reports TTFT / per-token
latency / throughput percentiles (``--json-out`` for machines):

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --trace 32 --rate 50 --slots 8 --json-out serve.json

MoE archs honour ``--backend`` (DESIGN.md §6) and ``--local-routing``
(Gate-Drop local path at decode: no all-to-all in the sharded decode
executable, DESIGN.md §9).

PRNG discipline: parameter init, prompt synthesis, and sampling each fold
a DISTINCT stream off ``--seed`` (folds 0/1/2) — reusing one key made
"random" prompts functions of the weights.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs import PagedKVConfig, get_config, reduced
from repro.models import init_model
from repro.obs import (Histogram, MetricsRegistry, Tracer, monotonic,
                       set_tracer)
from repro.serve import (ContinuousScheduler, GenerateConfig, PagedScheduler,
                         Request, make_generate_fn, paged_kv_bytes)


def synth_batch(cfg, key, batch: int, prompt_len: int):
    """Conditioning inputs for a batch of synthetic prompts; each field
    draws from its own fold of ``key``."""
    out = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 0), (batch, prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        out["img_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            out["frames"] = jax.random.normal(
                jax.random.fold_in(key, 2),
                (batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            out["enc_tokens"] = jax.random.randint(
                jax.random.fold_in(key, 3), (batch, 32), 3, cfg.vocab)
    return out


def synth_trace(cfg, key, n: int, rate: float, buckets, max_new: int):
    """Synthetic request trace: Poisson arrivals (exponential gaps at
    ``rate`` req/s), prompt lengths uniform over [2, max bucket], token
    budgets uniform over [2, max_new]."""
    rs = np.random.RandomState(np.asarray(
        jax.random.key_data(key) if hasattr(jax.random, "key_data")
        else key)[-1] & 0x7FFFFFFF)
    gaps = rs.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    reqs = []
    for i in range(n):
        plen = int(rs.randint(2, buckets[-1] + 1))
        budget = int(rs.randint(2, max_new + 1))
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 10 + i), (plen,), 3, cfg.vocab),
            np.int32)
        extras = {}
        row = synth_batch(cfg, jax.random.fold_in(key, 1000 + i), 1, 1)
        for k, v in row.items():
            if k != "tokens":
                extras[k] = np.asarray(v[0])
        reqs.append(Request(rid=i, tokens=toks, extras=extras,
                            max_new=budget, arrival=float(arrivals[i])))
    return reqs


def _pcts(xs):
    # NaN-safe through a registry histogram: np.percentile raised on an
    # empty sample list (zero-request traces); the snapshot never does
    h = Histogram("_pcts")
    for x in xs:
        h.observe(x)
    return h.percentiles((50, 90, 99))


def trace_comm_section(cfg, gen, sched, ep: int) -> dict:
    """Price every executed device call of a trace with the substrate
    bytes model (comm/cost.py, DESIGN.md §10): per-tick wire bytes a
    sharded deployment at expert-parallel width ``ep`` would move, as
    totals + percentiles. Decode ticks under ``--local-routing`` move
    zero bytes (the Gate-Drop local path has no all-to-all)."""
    from repro.comm import layer_cost
    from repro.training.steps import n_moe_layers
    nl = n_moe_layers(cfg)
    per_tick = []
    exposed_tick = []
    for kind, toks in sched.tick_log:
        if kind == "decode" and gen.local_routing:
            per_tick.append(0.0)
            exposed_tick.append(0.0)
            continue
        c = layer_cost(cfg, tokens_per_shard=max(toks // ep, 1), ep=ep,
                       is_training=False)
        per_tick.append(c["wire_bytes"] * nl)
        exposed_tick.append(c["exposed_wire_bytes"] * nl)
    return {
        "substrate": cfg.moe.comm.substrate,
        "quant": cfg.moe.comm.quant,
        "n_chunks": cfg.moe.comm.n_chunks,
        "ep_model": ep,
        "n_ticks": len(per_tick),
        "wire_bytes_total": float(sum(per_tick)),
        # §14 split: wire an overlapped substrate cannot hide behind the
        # expert FFN of the same tick (= total for non-overlapped)
        "exposed_bytes_total": float(sum(exposed_tick)),
        "wire_bytes_per_tick": _pcts(per_tick) if per_tick else {},
    }


def trace_cache_section(sched: PagedScheduler) -> dict:
    """Paged-KV occupancy report for a --trace run: page/prefix stats
    mirror the comm section's role for DESIGN.md §13 — what the arena
    actually held vs what a slot pool would have pinned."""
    lay = sched.layout
    return {
        "page_size": lay.page_size,
        "n_pages": lay.n_pages,
        "n_blocks": lay.n_blocks,
        "peak_pages_in_use": sched.stats["peak_pages_in_use"],
        "peak_kv_bytes": int(sched.stats["peak_pages_in_use"]
                             * sched.page_bytes),
        "arena_kv_bytes": int(paged_kv_bytes(sched.pool, sched.cfg))
        if sched.pool is not None else 0,
        "prefix_hit_rate": (sched.stats["prefix_hits"]
                            / max(sched.stats["prefix_lookups"], 1)),
        "prefix_hits": sched.stats["prefix_hits"],
        "cow_copies": sched.stats["cow_copies"],
        "preemptions": sched.stats["preemptions"],
        "swap_ins": sched.stats["swap_ins"],
        "mean_alive_slots": (float(np.mean(sched.alive_log))
                             if sched.alive_log else 0.0),
    }


def run_trace(args, cfg, params, gen, key_prompts, key_sample) -> dict:
    buckets = tuple(int(b) for b in args.buckets.split(","))
    # trace synthesis draws from the PROMPT stream; key_sample feeds only
    # the scheduler's per-request sampling folds — distinct parent folds,
    # so prompt and sampling keys can never collide
    reqs = synth_trace(cfg, key_prompts, args.trace,
                       args.rate, buckets, gen.max_new)
    reg = MetricsRegistry()
    if args.paged:
        paged = PagedKVConfig(page_size=args.page_size,
                              n_pages=args.pages,
                              prefix_caching=not args.no_prefix_cache)
        sched = PagedScheduler(params, cfg, gen, paged=paged,
                               n_slots=args.slots, prefill_buckets=buckets,
                               admit_width=args.admit_width,
                               rng=key_sample, registry=reg)
    else:
        sched = ContinuousScheduler(params, cfg, gen, n_slots=args.slots,
                                    prefill_buckets=buckets,
                                    admit_width=args.admit_width,
                                    rng=key_sample, registry=reg)
    t0 = monotonic()
    results = sched.run(reqs)
    wall = monotonic() - t0
    n_tok = int(sum(r.length for r in results))
    # percentiles come from the registry histograms the scheduler filled
    # at retire time — the registry is THE backing store (DESIGN.md §15)
    rec = {
        "mode": "continuous",
        "arch": cfg.arch_id,
        "n_requests": len(results),
        "n_tokens": n_tok,
        "wall_s": wall,
        "tok_s": n_tok / wall,
        "req_s": len(results) / wall,
        "ttft_s": reg.histogram("serve/ttft_s").percentiles((50, 90, 99)),
        "per_token_latency_s": reg.histogram(
            "serve/per_token_latency_s").percentiles((50, 90, 99)),
        "scheduler": dict(sched.stats),
        "slots": args.slots,
        "buckets": list(buckets),
        "local_routing": gen.local_routing,
    }
    if cfg.moe is not None:
        rec["comm"] = trace_comm_section(cfg, gen, sched, args.comm_ep)
    if args.paged:
        rec["cache"] = trace_cache_section(sched)
    # throughput + scheduler stats land in the same store so one
    # --metrics-out file carries the whole serving picture
    reg.gauge("serve/wall_s").set(wall)
    reg.gauge("serve/tok_s").set(rec["tok_s"])
    reg.gauge("serve/req_s").set(rec["req_s"])
    for k, v in sched.stats.items():
        reg.gauge(f"serve/stats/{k}").set(float(v))
    if args.metrics_out:
        _write_metrics(reg, args.metrics_out)
    return rec


def _write_metrics(reg: MetricsRegistry, path: str) -> None:
    """.prom/.txt -> Prometheus text exposition, anything else -> JSON."""
    if path.endswith((".prom", ".txt")):
        reg.to_prometheus(path)
    else:
        reg.to_json(path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sampling pool size (0 = full vocab)")
    ap.add_argument("--beam", type=int, default=1,
                    help=">1 = beam search (overrides sampling)")
    ap.add_argument("--eos", type=int, default=GenerateConfig.eos_id,
                    help="EOS token id for early exit (-1 = generate "
                         "max-new tokens unconditionally); default matches "
                         "GenerateConfig.eos_id")
    ap.add_argument("--backend", default=None,
                    choices=[None, "auto", "oracle", "sharded", "pallas",
                             "pallas_fused"],
                    help="MoE execution backend (DESIGN.md §6, §11)")
    from repro.configs.base import COMM_SUBSTRATES
    ap.add_argument("--comm", default=None,
                    choices=[None, *COMM_SUBSTRATES],
                    help="communication substrate for expert dispatch "
                         "(DESIGN.md §10, §14)")
    ap.add_argument("--comm-quant", default=None,
                    choices=[None, "int8", "fp8"],
                    help="wire dtype for compressed substrates")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="overlapped substrates: capacity micro-chunks "
                         "pipelined behind expert compute")
    ap.add_argument("--comm-ep", type=int, default=1,
                    help="expert-parallel width the --trace comm "
                         "accounting prices the wire at (default 1 = "
                         "this process)")
    ap.add_argument("--local-routing", action="store_true",
                    help="Gate-Drop local routing at decode: MoE tokens "
                         "stay in the local expert group, no all-to-all "
                         "in the decode executable (DESIGN.md §9)")
    ap.add_argument("--flash-decode", action="store_true",
                    help="route full-cache decode attention through the "
                         "kernels.flash_decode Pallas kernel (DESIGN.md "
                         "§11; ring/window caches keep the reference path)")
    # continuous batching
    ap.add_argument("--trace", type=int, default=0,
                    help="N>0: serve N synthetic Poisson-arrival requests "
                         "through the continuous-batching scheduler")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="trace arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slot-pool size")
    ap.add_argument("--admit-width", type=int, default=None,
                    help="admission group width (default min(4, slots))")
    ap.add_argument("--buckets", default="8,16,32,64",
                    help="prefill length buckets, comma-separated")
    ap.add_argument("--paged", action="store_true",
                    help="serve --trace through the paged-KV scheduler "
                         "(block-table decode cache, DESIGN.md §13)")
    ap.add_argument("--page-size", type=int,
                    default=PagedKVConfig.page_size,
                    help="KV page size in tokens (--paged)")
    ap.add_argument("--pages", type=int, default=PagedKVConfig.n_pages,
                    help="physical page count (0 = n_slots_equiv full-"
                         "length requests' worth, --paged)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix page caching (--paged)")
    ap.add_argument("--json-out", default=None,
                    help="write metrics JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="enable the span tracer and write a Chrome-trace/"
                         "Perfetto JSON of scheduler ticks here "
                         "(DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the serving metrics registry here "
                         "(.prom/.txt = Prometheus text, else JSON)")
    args = ap.parse_args()

    tracer = Tracer(enabled=bool(args.trace_out))
    set_tracer(tracer)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.moe is not None and (args.backend or args.comm
                                or args.comm_quant
                                or args.comm_chunks is not None):
        comm = dataclasses.replace(
            cfg.moe.comm,
            substrate=args.comm or cfg.moe.comm.substrate,
            quant=args.comm_quant or cfg.moe.comm.quant,
            n_chunks=args.comm_chunks if args.comm_chunks is not None
            else cfg.moe.comm.n_chunks)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, backend=args.backend or cfg.moe.backend, comm=comm))
    # distinct PRNG streams: params / prompts / sampling
    key = jax.random.PRNGKey(args.seed)
    key_params = jax.random.fold_in(key, 0)
    key_prompts = jax.random.fold_in(key, 1)
    key_sample = jax.random.fold_in(key, 2)
    params = init_model(key_params, cfg)

    gen = GenerateConfig(max_new=args.max_new, temperature=args.temperature,
                         top_k=args.top_k, beam_width=args.beam,
                         eos_id=args.eos, local_routing=args.local_routing,
                         flash_decode=args.flash_decode)

    if args.trace > 0:
        rec = run_trace(args, cfg, params, gen, key_prompts, key_sample)
        print(f"arch={rec['arch']} served {rec['n_requests']} requests, "
              f"{rec['n_tokens']} tokens in {rec['wall_s']:.2f} s "
              f"({rec['tok_s']:.0f} tok/s)")
        print(f"TTFT p50/p90/p99: "
              + "/".join(f"{rec['ttft_s'][p]*1e3:.1f}" for p in (50, 90, 99))
              + " ms; per-token latency p50/p90/p99: "
              + "/".join(f"{rec['per_token_latency_s'][p]*1e3:.2f}"
                         for p in (50, 90, 99)) + " ms")
        print("scheduler:", rec["scheduler"])
        if "comm" in rec:
            c = rec["comm"]
            pt = c["wire_bytes_per_tick"]
            print(f"comm[{c['substrate']}@ep={c['ep_model']}]: "
                  f"{c['wire_bytes_total']/2**20:.2f} MiB wire over "
                  f"{c['n_ticks']} ticks; per-tick KiB p50/p90/p99: "
                  + "/".join(f"{pt[p]/2**10:.1f}" for p in (50, 90, 99)))
        if "cache" in rec:
            k = rec["cache"]
            print(f"cache[paged {k['page_size']}tok]: peak "
                  f"{k['peak_pages_in_use']}/{k['n_pages']} pages "
                  f"({k['peak_kv_bytes']/2**20:.2f} MiB KV), prefix hit "
                  f"rate {k['prefix_hit_rate']:.2f}, {k['cow_copies']} "
                  f"COW, {k['preemptions']} preemptions")
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(rec, f, indent=1)
        if args.trace_out:
            tracer.export(args.trace_out)
        return

    batch = synth_batch(cfg, key_prompts, args.batch, args.prompt_len)
    fn = make_generate_fn(cfg, gen)
    t0 = monotonic()
    with tracer.span("generate.compile"):
        res = jax.block_until_ready(fn(params, batch, key_sample))
    t_compile = monotonic() - t0
    t0 = monotonic()
    with tracer.span("generate.steady"):
        res = jax.block_until_ready(fn(params, batch, key_sample))
    dt = monotonic() - t0
    n_tok = int(np.asarray(res.lengths).sum())
    print(f"arch={cfg.arch_id} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new} beam={args.beam}")
    print(f"compile+first: {t_compile:.2f} s; steady: {dt*1e3:.1f} ms "
          f"({dt/max(int(res.steps), 1)*1e3:.2f} ms/step, "
          f"{n_tok/dt:.0f} tok/s)")
    print("sample:", np.asarray(res.tokens)[0][:16].tolist())
    if args.json_out:
        rec = {"mode": "oneshot", "arch": cfg.arch_id,
               "n_tokens": n_tok, "wall_s": dt, "tok_s": n_tok / dt,
               "compile_s": t_compile}
        with open(args.json_out, "w") as f:
            json.dump(rec, f, indent=1)
    if args.trace_out:
        tracer.export(args.trace_out)
    if args.metrics_out:
        reg = MetricsRegistry()
        reg.gauge("serve/compile_s").set(t_compile)
        reg.gauge("serve/wall_s").set(dt)
        reg.gauge("serve/tok_s").set(n_tok / dt)
        _write_metrics(reg, args.metrics_out)


if __name__ == "__main__":
    main()
