"""Batched greedy serving loop (prefill + decode) for any arch.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 8 --prompt-len 64 --max-new 32

MoE archs honour ``--backend`` (DESIGN.md §6): oracle / sharded / pallas
execution of the expert layers during prefill+decode.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import decode_step, init_model, prefill
from repro.training import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default=None,
                    choices=[None, "auto", "oracle", "sharded", "pallas"],
                    help="MoE execution backend (DESIGN.md §6)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.backend and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, backend=args.backend))
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    max_seq = args.prompt_len + args.max_new
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(
                key, (args.batch, 32), 3, cfg.vocab)

    t0 = time.time()
    logits, caches = prefill(params, batch, cfg, max_seq=max_seq)
    cur = logits.argmax(-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    step = make_serve_step(cfg)
    outs = []
    t0 = time.time()
    for i in range(args.max_new):
        logits, caches = step(params, caches, cur, args.prompt_len + i)
        cur = logits.argmax(-1).astype(jnp.int32)
        outs.append(np.asarray(cur)[:, 0])
    dt = time.time() - t0
    gen = np.stack(outs, 1)
    print(f"arch={cfg.arch_id} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"prefill: {t_prefill*1e3:.1f} ms; decode: "
          f"{dt/args.max_new*1e3:.2f} ms/token "
          f"({args.batch*args.max_new/dt:.0f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
