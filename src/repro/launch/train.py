"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch zcode-m3-base --reduced \
      --steps 200 --batch 16 --task mt --gd-mode gate_drop --gd-rate 0.3

Runs on CPU at reduced scale (or on a real mesh via --mesh d,m). Training
executes through the scan-fused Trainer (DESIGN.md §8): `--chunk` steps
per compiled dispatch, prefetched input pipeline, metrics fetched at
chunk boundaries only. Uses the paper's host_cond strategy by default
(`--strategy`): same-decision runs dispatch to two executables, the
dropped one free of all-to-all; the per-step consensus bit comes from
the shared (seed, step) PRNG fold — see DESIGN.md §2.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.core.moe import ParallelContext
from repro.data import MTTaskConfig, MultilingualMT, LMTaskConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.metrics import corpus_bleu, strip_special
from repro.obs import MetricsRegistry, Tracer, router_health, set_tracer
from repro.serve import GenerateConfig, generate
from repro.training import Trainer


def build_batch_fn(cfg, args):
    """Per-step numpy batches (the Trainer stacks them into chunks; keep
    this pure host work — it runs on the prefetch thread)."""
    if args.task == "mt":
        task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=args.langs,
                                           max_len=args.seq))
        return task, task.train_batches(args.batch)
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=args.seq))
    return task, lambda step: task.sample_batch(step, args.batch)


def greedy_bleu(params, cfg, task, *, n=32, max_new=36, seed=10_000,
                ctx=None, lang=None):
    """Greedy decode a validation batch -> corpus BLEU (MT task only).

    THE corpus-BLEU-via-engine helper — the BLEU benchmarks call it too
    (benchmarks/common.py::decode_bleu). Decodes through the compiled
    engine (repro.serve, DESIGN.md §7): the first generated token comes
    from the prefill logits and the first decode_step runs at index
    ``prompt_len`` — the previous hand-rolled loop here fed index 0 after
    prefill, clobbering the BOS cache slot and corrupting every reported
    BLEU. ``lang`` restricts the validation batch to one language
    (Table-4 per-direction splits)."""
    kw = {} if lang is None else {"lang": lang}
    b = task.sample_batch(seed, n, **kw)
    batch = {"enc_tokens": jnp.asarray(b["enc_tokens"]),
             "tokens": jnp.asarray(b["tokens"][:, :1])}   # BOS
    res = generate(params, batch, cfg, GenerateConfig(max_new=max_new),
                   ctx=ctx)
    hyps = [strip_special(h) for h in np.asarray(res.tokens)]
    refs = [strip_special(r) for r in b["labels"]]
    return corpus_bleu(hyps, refs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zcode-m3-base")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--langs", type=int, default=8)
    ap.add_argument("--task", default="mt", choices=["mt", "lm"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--schedule", default="inverse_sqrt",
                    choices=["inverse_sqrt", "cosine", "constant"],
                    help="LR schedule (optim/adam.py)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches per step "
                         "(--batch must divide evenly)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=8,
                    help="steps per scan-fused train dispatch (DESIGN.md §8)")
    ap.add_argument("--strategy", default="host_cond",
                    choices=["traced_cond", "host_cond"],
                    help="gating-dropout execution strategy (DESIGN.md §5); "
                         "host_cond is paper-faithful")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="synthesize chunks inline instead of on the "
                         "background prefetch thread")
    ap.add_argument("--gd-mode", default=None,
                    choices=[None, "off", "gate_drop", "gate_expert_drop"])
    ap.add_argument("--gd-rate", type=float, default=None)
    ap.add_argument("--router", default=None,
                    choices=[None, "softmax", "sigmoid", "hash"])
    ap.add_argument("--backend", default=None,
                    choices=[None, "auto", "oracle", "sharded", "pallas",
                             "pallas_fused"],
                    help="MoE execution backend (DESIGN.md §6, §11)")
    from repro.configs.base import COMM_SUBSTRATES
    ap.add_argument("--comm", default=None,
                    choices=[None, *COMM_SUBSTRATES],
                    help="communication substrate for expert dispatch "
                         "(DESIGN.md §10, §14)")
    ap.add_argument("--comm-quant", default=None, choices=[None, "int8", "fp8"],
                    help="wire dtype for compressed substrates")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="overlapped substrates: capacity micro-chunks "
                         "pipelined behind expert compute (actual count "
                         "= largest divisor of the capacity <= this)")
    ap.add_argument("--ep-inner", type=int, default=None,
                    help="hierarchical substrate: intra-tier group size "
                         "(must divide ep; default auto ~sqrt)")
    ap.add_argument("--mesh", default=None, help="e.g. 4,2 => (data,model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir "
                         "(params + opt + step) and continue training")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--no-metrics-frame", action="store_true",
                    help="drop the in-graph router/comm MetricsFrame "
                         "outputs (telemetry only — the loss/update math "
                         "is bitwise identical either way, DESIGN.md §15)")
    ap.add_argument("--trace-out", default=None,
                    help="enable the span tracer and write a Chrome-trace/"
                         "Perfetto JSON of the run here (DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics-registry summary of the run "
                         "(.prom/.txt = Prometheus text, else JSON)")
    ap.add_argument("--jax-profile", default=None, metavar="LOGDIR",
                    help="wrap the run in a jax.profiler trace window "
                         "(TensorBoard/Perfetto logdir)")
    args = ap.parse_args()

    tracer = Tracer(enabled=bool(args.trace_out))
    set_tracer(tracer)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.moe is not None and (args.gd_mode or args.gd_rate is not None
                                or args.router or args.backend or args.comm
                                or args.comm_quant
                                or args.comm_chunks is not None
                                or args.ep_inner is not None):
        gd = cfg.moe.gating_dropout
        gd = dataclasses.replace(
            gd,
            mode=args.gd_mode if args.gd_mode else gd.mode,
            rate=args.gd_rate if args.gd_rate is not None else gd.rate)
        comm = dataclasses.replace(
            cfg.moe.comm,
            substrate=args.comm or cfg.moe.comm.substrate,
            quant=args.comm_quant or cfg.moe.comm.quant,
            n_chunks=args.comm_chunks if args.comm_chunks is not None
            else cfg.moe.comm.n_chunks,
            ep_inner=args.ep_inner if args.ep_inner is not None
            else cfg.moe.comm.ep_inner)
        moe = dataclasses.replace(
            cfg.moe, gating_dropout=gd, comm=comm,
            router_type=args.router or cfg.moe.router_type,
            backend=args.backend or cfg.moe.backend)
        cfg = dataclasses.replace(cfg, moe=moe)

    ctx = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        ctx = ParallelContext(mesh=make_mesh(shape, ("data", "model")[:len(shape)]))

    tc = TrainConfig(lr=args.lr, warmup_steps=args.warmup, steps=args.steps,
                     seed=args.seed, schedule=args.schedule,
                     microbatches=args.microbatches,
                     metrics_frame=not args.no_metrics_frame)
    task, batch_fn = build_batch_fn(cfg, args)
    eval_fn = None
    if args.eval_every and args.task == "mt":
        eval_fn = lambda state, step: {  # noqa: E731
            "bleu": greedy_bleu(state["params"], cfg, task, ctx=ctx)}
    trainer = Trainer(cfg, tc, batch_fn, ctx=ctx, chunk=args.chunk,
                      strategy=args.strategy, ckpt_dir=args.ckpt_dir,
                      eval_every=args.eval_every, eval_fn=eval_fn,
                      log_every=args.log_every,
                      prefetch=not args.no_prefetch)
    if args.resume:
        assert args.ckpt_dir, "--resume needs --ckpt-dir"
        # restore() continues at the ABSOLUTE step: after --resume both the
        # data stream (batch_fn) and the Gating-Dropout consensus PRNG
        # (seed, step) pick up exactly where the checkpointed run left off
        print(f"resumed {args.ckpt_dir} @ step {trainer.restore()}")
    if args.jax_profile:
        with tracer.profile_window(args.jax_profile):
            state, history = trainer.run()
    else:
        state, history = trainer.run()
    if args.ckpt_dir:
        print(f"checkpoint -> {args.ckpt_dir}")
    gd = cfg.moe.gating_dropout if cfg.moe is not None else None
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"arch": cfg.arch_id, "history": history,
                       "gd": dataclasses.asdict(gd) if gd else None}, f)
    if args.trace_out:
        tracer.export(args.trace_out)
    if args.metrics_out:
        reg = MetricsRegistry()
        loss_h = reg.histogram("train/loss", "recorded per-step loss")
        tok_h = reg.histogram("train/tok_s", "tokens/s at record points")
        for rec in history:
            loss_h.observe(rec["loss"])
            tok_h.observe(rec["tok_s"])
        if history:
            reg.gauge("train/final_loss").set(history[-1]["loss"])
            reg.gauge("train/wall_s").set(history[-1]["time_s"])
        rh = router_health(history)
        if rh["records"]:
            for k, v in rh.items():
                reg.gauge(f"train/router/{k}").set(float(v))
        if path_is_prom := args.metrics_out.endswith((".prom", ".txt")):
            reg.to_prometheus(args.metrics_out)
        if not path_is_prom:
            reg.to_json(args.metrics_out)


if __name__ == "__main__":
    main()
