"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch zcode-m3-base --reduced \
      --steps 200 --batch 16 --task mt --gd-mode gate_drop --gd-rate 0.3

Runs on CPU at reduced scale (or on a real mesh via --mesh d,m). Uses the
paper's host_cond strategy by default: two executables, the dropped one
free of all-to-all; the per-step consensus bit comes from the shared
(seed, step) PRNG fold — see DESIGN.md §2.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import drop_decision_host
from repro.core.moe import ParallelContext
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import MTTaskConfig, MultilingualMT, LMTaskConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.metrics import corpus_bleu, strip_special
from repro.models import init_model
from repro.serve import GenerateConfig, generate
from repro.training import init_train_state, make_eval_step, make_train_step


def build_batch_fn(cfg, args):
    if args.task == "mt":
        task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=args.langs,
                                           max_len=args.seq))
        def fn(step):
            b = task.sample_batch(step, args.batch)
            return {k: jnp.asarray(v) for k, v in b.items() if k != "lang"}
        return task, fn
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=args.seq))
    def fn(step):
        return {k: jnp.asarray(v) for k, v in
                task.sample_batch(step, args.batch).items()}
    return task, fn


def greedy_bleu(params, cfg, task, *, n=32, max_new=36, seed=10_000,
                ctx=None, lang=None):
    """Greedy decode a validation batch -> corpus BLEU (MT task only).

    THE corpus-BLEU-via-engine helper — the BLEU benchmarks call it too
    (benchmarks/common.py::decode_bleu). Decodes through the compiled
    engine (repro.serve, DESIGN.md §7): the first generated token comes
    from the prefill logits and the first decode_step runs at index
    ``prompt_len`` — the previous hand-rolled loop here fed index 0 after
    prefill, clobbering the BOS cache slot and corrupting every reported
    BLEU. ``lang`` restricts the validation batch to one language
    (Table-4 per-direction splits)."""
    kw = {} if lang is None else {"lang": lang}
    b = task.sample_batch(seed, n, **kw)
    batch = {"enc_tokens": jnp.asarray(b["enc_tokens"]),
             "tokens": jnp.asarray(b["tokens"][:, :1])}   # BOS
    res = generate(params, batch, cfg, GenerateConfig(max_new=max_new),
                   ctx=ctx)
    hyps = [strip_special(h) for h in np.asarray(res.tokens)]
    refs = [strip_special(r) for r in b["labels"]]
    return corpus_bleu(hyps, refs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zcode-m3-base")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--langs", type=int, default=8)
    ap.add_argument("--task", default="mt", choices=["mt", "lm"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gd-mode", default=None,
                    choices=[None, "off", "gate_drop", "gate_expert_drop"])
    ap.add_argument("--gd-rate", type=float, default=None)
    ap.add_argument("--router", default=None,
                    choices=[None, "softmax", "sigmoid", "hash"])
    ap.add_argument("--backend", default=None,
                    choices=[None, "auto", "oracle", "sharded", "pallas"],
                    help="MoE execution backend (DESIGN.md §6)")
    ap.add_argument("--mesh", default=None, help="e.g. 4,2 => (data,model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir "
                         "(params + opt + step) and continue training")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.moe is not None and (args.gd_mode or args.gd_rate is not None
                                or args.router or args.backend):
        gd = cfg.moe.gating_dropout
        gd = dataclasses.replace(
            gd,
            mode=args.gd_mode if args.gd_mode else gd.mode,
            rate=args.gd_rate if args.gd_rate is not None else gd.rate)
        moe = dataclasses.replace(
            cfg.moe, gating_dropout=gd,
            router_type=args.router or cfg.moe.router_type,
            backend=args.backend or cfg.moe.backend)
        cfg = dataclasses.replace(cfg, moe=moe)

    ctx = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        ctx = ParallelContext(mesh=make_mesh(shape, ("data", "model")[:len(shape)]))

    tc = TrainConfig(lr=args.lr, warmup_steps=args.warmup, steps=args.steps,
                     seed=args.seed)
    task, batch_fn = build_batch_fn(cfg, args)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    state = init_train_state(params, tc)
    start_step = 0
    if args.resume:
        assert args.ckpt_dir, "--resume needs --ckpt-dir"
        assert latest_step(args.ckpt_dir) is not None, \
            f"--resume: no checkpoint in {args.ckpt_dir}"
        state, meta = restore_checkpoint(args.ckpt_dir, state)
        start_step = int(meta["step"])
        print(f"resumed {args.ckpt_dir} @ step {start_step}")
    step_fn = make_train_step(cfg, tc, ctx)
    gd = cfg.moe.gating_dropout if cfg.moe is not None else None

    history = []
    t0 = time.time()
    tokens_done = 0
    # the loop index is the ABSOLUTE step: after --resume both the data
    # stream (batch_fn) and the Gating-Dropout consensus PRNG (seed, step)
    # continue exactly where the checkpointed run left off (DESIGN.md §2)
    for i in range(start_step, args.steps):
        batch = batch_fn(i)
        dec = drop_decision_host(gd, args.seed, i) if gd and gd.enabled else False
        state, m = step_fn(state, batch, bool(dec))
        tokens_done += int(batch["tokens"].size)
        if i % args.log_every == 0 or i == args.steps - 1:
            el = time.time() - t0
            rec = {"step": i, "loss": float(m["loss"]), "acc": float(m["acc"]),
                   "tok_s": tokens_done / max(el, 1e-9), "time_s": el}
            if "balance" in m:
                rec["balance"] = float(m["balance"])
            if args.eval_every and args.task == "mt" and \
                    (i % args.eval_every == 0 or i == args.steps - 1):
                rec["bleu"] = greedy_bleu(state["params"], cfg, task, ctx=ctx)
            history.append(rec)
            print(json.dumps(rec))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state,
                        {"arch": cfg.arch_id})
        print(f"checkpoint -> {args.ckpt_dir}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"arch": cfg.arch_id, "history": history,
                       "gd": dataclasses.asdict(gd) if gd else None}, f)


if __name__ == "__main__":
    main()
