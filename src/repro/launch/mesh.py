"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)   # older jax: axes are Auto by default


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks (e.g. (4, 2) on 8 CPU devices)."""
    return _mk(tuple(shape), tuple(axes))


def abstract_mesh(shape, axes):
    """AbstractMesh across jax versions: no devices needed, spec-validity
    checks only (used by tests against the production mesh shapes)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))        # >= 0.5 API
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))          # 0.4.x API
