"""CLI for the compiled-program lint suite (DESIGN.md §12).

    python -m repro.launch.lint                    # full report
    python -m repro.launch.lint --gate             # CI: exit 1 on errors
    python -m repro.launch.lint --json-out r.json  # machine-readable
    python -m repro.launch.lint --table            # pass x executable grid
    python -m repro.launch.lint --only moe_layer/dense --passes no-collectives

Must configure the 8-device CPU mesh BEFORE jax initializes, hence the
env mutation at module top (same pattern as launch/dryrun.py).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="HLO/jaxpr lint over every registered executable")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if any unsuppressed error survives")
    ap.add_argument("--json-out", metavar="PATH",
                    help="write the full report as JSON")
    ap.add_argument("--table", action="store_true",
                    help="print the static pass x executable matrix")
    ap.add_argument("--only", action="append", default=None,
                    metavar="EXECUTABLE",
                    help="restrict to named executable(s)")
    ap.add_argument("--passes", action="append", default=None,
                    metavar="PASS", help="restrict to pass id(s)")
    ap.add_argument("--static-only", action="store_true",
                    help="skip scenario passes (pure lowering)")
    ap.add_argument("--list", action="store_true",
                    help="list executables and passes, run nothing")
    args = ap.parse_args(argv)

    from repro.analysis.executables import available_executables
    from repro.analysis.lint import (format_lint_table, format_report, gate,
                                     lint_table, report_json, run_lint)
    from repro.analysis.passes import available_passes, get_pass

    if args.list:
        print("passes:")
        for p in available_passes():
            print(f"  {p:<16} {get_pass(p).doc.splitlines()[0]}")
        print("executables:")
        for n in available_executables():
            print(f"  {n}")
        return 0

    if args.table:
        print(format_lint_table(lint_table(only=args.only)))
        return 0

    findings = run_lint(only=args.only, passes=args.passes,
                        static_only=args.static_only)
    print(format_report(findings))
    ok, verdict = gate(findings)
    print(verdict)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(report_json(findings))
        print(f"wrote {args.json_out}")
    return 0 if (ok or not args.gate) else 1


if __name__ == "__main__":
    sys.exit(main())
