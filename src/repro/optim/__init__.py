from repro.optim.adam import adam_init, adam_update, global_norm, schedule

__all__ = ["adam_init", "adam_update", "global_norm", "schedule"]
