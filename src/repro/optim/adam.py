"""Adam optimizer + LR schedules, pure-pytree implementation.

Paper settings (§4.1): Adam, beta1=0.9, beta2=0.99, lr=0.03 with 5000
warmup steps and an inverse-square-root decay (Raffel et al., 2019).
``moment_dtype="bfloat16"`` halves optimizer memory for the huge archs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any
OptState = Dict[str, Any]


def schedule(step: jax.Array, tc: TrainConfig) -> jax.Array:
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    w = float(max(tc.warmup_steps, 1))
    if tc.schedule == "inverse_sqrt":
        warm = s / w
        decay = jnp.sqrt(w / jnp.maximum(s, w))
        return tc.lr * jnp.minimum(warm, decay)
    if tc.schedule == "cosine":
        warm = jnp.minimum(s / w, 1.0)
        t = jnp.clip((s - w) / max(tc.steps - w, 1), 0.0, 1.0)
        return tc.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.asarray(tc.lr, jnp.float32)


def adam_init(params: Params, tc: TrainConfig) -> OptState:
    mdt = jnp.dtype(tc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adam_update(grads: Params, opt: OptState, params: Params,
                tc: TrainConfig) -> Tuple[Params, OptState, Dict]:
    step = opt["step"] + 1
    lr = schedule(step, tc)
    gnorm = global_norm(grads)
    if tc.grad_clip > 0:
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    b1, b2 = tc.b1, tc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(tc.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vn = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = mn / bc1
        vh = vn / bc2
        delta = lr * mh / (jnp.sqrt(vh) + tc.eps)
        if tc.weight_decay > 0:
            delta = delta + lr * tc.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                mn.astype(mdt), vn.astype(mdt))

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
