"""Unified observability layer (DESIGN.md §15).

Three parts, one invariant:

  * ``obs.trace``    — host-side span tracer on the repo's single
                       monotonic clock, Chrome-trace/Perfetto export;
  * ``obs.frame``    — typed host view over the in-graph router/comm
                       MetricsFrame the train chunks accumulate on
                       device;
  * ``obs.registry`` — counters/gauges/histograms/series backing the
                       serving schedulers' stats, with Prometheus/JSON
                       export.

The invariant: observability NEVER adds a host-device sync. The frame
rides the chunk's existing once-per-chunk ``device_get``; the tracer and
registry are pure host work (lint's host-sync pass runs the instrumented
tick scenarios to prove it).
"""
from repro.obs.frame import (FRAME_KEYS, MetricsFrame, load_imbalance,
                             router_health)
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                Series)
from repro.obs.trace import Tracer, get_tracer, monotonic, set_tracer

__all__ = [
    "Counter", "FRAME_KEYS", "Gauge", "Histogram", "MetricsFrame",
    "MetricsRegistry", "Series", "Tracer", "get_tracer", "load_imbalance",
    "monotonic", "router_health", "set_tracer",
]
