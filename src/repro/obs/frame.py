"""Typed view over the in-graph router/comm MetricsFrame (DESIGN.md §15).

The frame itself lives ON DEVICE: every train step's metric dict carries
the per-step router-health and wire counters (built inside the MoE aux
path — core/moe.py, comm/substrate.py — and surfaced by
``training/steps.py::total_loss`` when ``TrainConfig.metrics_frame`` is
on). The scan-fused chunk stacks them to a leading K axis and the
Trainer fetches them in its existing once-per-chunk ``jax.device_get``
— observability adds ZERO extra host syncs, and with the frame off the
executables' loss math is bitwise unchanged
(``tests/test_obs.py::test_metrics_frame_bitwise_non_interference``).

This module is the HOST half: numpy-only typing and summary math over
the fetched arrays (no jax import — constructing a frame can never touch
a device).

Frame schema (per step; E = n_experts):
    expert_load        (E,)  mean per-expert routed load, layer-averaged
                             (sums to top_k on fully-routed steps)
    router_entropy     ()    mean per-token routing entropy, nats
    dropped_frac       ()    capacity-dropped fraction of dispatch slots
    gate_dropped       ()    the step's Gating-Dropout consensus bit
    comm_a2a_calls     ()    all-to-all ops this step's forward launched
    comm_bytes         ()    payload bytes entering the wire
    comm_wire_bytes    ()    per-device bytes actually on the wire
    comm_exposed_bytes ()    wire NOT hidden behind expert compute (§14)
    comm_hidden_bytes  ()    wire pipelined behind expert compute (§14)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["FRAME_KEYS", "MetricsFrame", "load_imbalance", "router_health"]

FRAME_KEYS = ("expert_load", "router_entropy", "dropped_frac",
              "gate_dropped", "comm_a2a_calls", "comm_bytes",
              "comm_wire_bytes", "comm_exposed_bytes", "comm_hidden_bytes")


def load_imbalance(load: np.ndarray) -> np.ndarray:
    """max/mean over the expert axis of a (..., E) load histogram — 1.0
    is perfect balance, E is total collapse onto one expert. Steps that
    routed nothing (gate-dropped under expert-drop) report 0."""
    load = np.asarray(load, np.float64)
    mean = load.mean(axis=-1)
    return np.where(mean > 0.0,
                    load.max(axis=-1) / np.maximum(mean, 1e-12), 0.0)


@dataclasses.dataclass
class MetricsFrame:
    """The fetched frame of one train chunk: every field stacked to a
    leading K (steps-in-chunk) axis."""
    expert_load: np.ndarray          # (K, E)
    router_entropy: np.ndarray       # (K,)
    dropped_frac: np.ndarray         # (K,)
    gate_dropped: np.ndarray         # (K,)
    comm_a2a_calls: np.ndarray       # (K,)
    comm_bytes: np.ndarray           # (K,)
    comm_wire_bytes: np.ndarray      # (K,)
    comm_exposed_bytes: np.ndarray   # (K,)
    comm_hidden_bytes: np.ndarray    # (K,)

    @classmethod
    def from_metrics(cls, ms: Dict[str, Any]) -> Optional["MetricsFrame"]:
        """Build from a fetched chunk-metrics dict; None when the frame
        keys are absent (dense model, or ``metrics_frame=False``)."""
        if not all(k in ms for k in FRAME_KEYS):
            return None
        return cls(**{k: np.asarray(ms[k]) for k in FRAME_KEYS})

    def __len__(self) -> int:
        return int(self.router_entropy.shape[0])

    def load_imbalance(self) -> np.ndarray:
        """(K,) per-step expert-load imbalance (max/mean)."""
        return load_imbalance(self.expert_load)

    def summary(self) -> Dict[str, float]:
        """Chunk-level scalars. Router health (entropy / imbalance /
        dropped_frac) averages ROUTED steps only — gate-dropped
        expert-drop steps route nothing and would dilute the signal
        toward zero; wire totals sum over all steps."""
        routed = np.asarray(self.gate_dropped) < 0.5
        n_routed = int(routed.sum())

        def rmean(x):
            return float(np.asarray(x)[routed].mean()) if n_routed else 0.0

        return {
            "steps": len(self),
            "routed_steps": n_routed,
            "gate_drop_rate": float(np.mean(self.gate_dropped)),
            "router_entropy": rmean(self.router_entropy),
            "load_imbalance": rmean(self.load_imbalance()),
            "dropped_frac": rmean(self.dropped_frac),
            "wire_bytes_total": float(np.sum(self.comm_wire_bytes)),
            "exposed_bytes_total": float(np.sum(self.comm_exposed_bytes)),
            "hidden_bytes_total": float(np.sum(self.comm_hidden_bytes)),
            "a2a_calls_total": float(np.sum(self.comm_a2a_calls)),
        }


def router_health(history: List[Dict[str, Any]]) -> Dict[str, float]:
    """Router-health summary over Trainer ``history`` records (which
    carry the per-record frame scalars when the frame was on): mean
    entropy / imbalance over routed records, plus the realized
    gate-drop rate. Used by ``benchmarks/fig6_rate_sweep.py`` to report
    the paper's regularization signal alongside loss."""
    recs = [r for r in history if "router_entropy" in r]
    if not recs:
        return {"records": 0, "router_entropy": float("nan"),
                "load_imbalance": float("nan"),
                "gate_drop_rate": float("nan")}
    routed = [r for r in recs if r.get("gate_dropped", 0.0) < 0.5]
    use = routed if routed else recs
    return {
        "records": len(recs),
        "router_entropy": float(np.mean([r["router_entropy"]
                                         for r in use])),
        "load_imbalance": float(np.mean([r["load_imbalance"]
                                         for r in use])),
        "gate_drop_rate": float(np.mean([r.get("gate_dropped", 0.0)
                                         for r in recs])),
    }
