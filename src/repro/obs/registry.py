"""Serving metrics registry: counters / gauges / histograms / series
with percentile snapshots and Prometheus-style + JSON export
(DESIGN.md §15).

One registry is THE backing store of a serving process: the schedulers'
``tick_log``/``alive_log`` are thin views over two registry ``Series``,
TTFT / per-token latency land in registry ``Histogram``s at retire time,
and ``launch/serve.py`` builds its reported percentiles from the
histogram snapshots instead of ad-hoc ``np.percentile`` calls (which
raised on zero-request traces — snapshots are NaN-safe).

Everything here is host-side numpy/python: observing a metric never
touches a device, so instrumented tick loops stay green under the
``analysis.hostsync`` guard.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "Series"]

DEFAULT_PERCENTILES = (50, 90, 99)

_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    n = _PROM_NAME.sub("_", name)
    return n if not n[:1].isdigit() else "_" + n


class Counter:
    """Monotonically increasing total."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} cannot decrease (inc {n})"
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-written value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Raw-sample histogram with NaN-safe percentile snapshots.

    Samples are kept exactly (serving traces are bounded, and exact
    percentiles beat bucket-quantization error at these sizes);
    ``percentiles`` matches ``np.percentile`` bit-for-bit on non-empty
    data and returns NaN — never raises — on empty data
    (the `launch/serve.py::_pcts` zero-request crash, ISSUE 10)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentiles(self, ps: Iterable[float] = DEFAULT_PERCENTILES
                    ) -> Dict[float, float]:
        if not self.samples:
            return {p: float("nan") for p in ps}
        xs = np.asarray(self.samples, np.float64)
        return {p: float(np.percentile(xs, p)) for p in ps}

    def snapshot(self) -> Dict[str, Any]:
        if not self.samples:
            nan = float("nan")
            return {"type": self.kind, "count": 0, "sum": 0.0,
                    "mean": nan, "min": nan, "max": nan,
                    "percentiles": self.percentiles()}
        xs = np.asarray(self.samples, np.float64)
        return {"type": self.kind, "count": int(xs.size),
                "sum": float(xs.sum()), "mean": float(xs.mean()),
                "min": float(xs.min()), "max": float(xs.max()),
                "percentiles": self.percentiles()}


class Series:
    """Ordered (label, value) pairs — the registry type backing the
    schedulers' ``tick_log`` (label = tick kind, value = tokens) and
    ``alive_log`` (unlabeled). ``items``/``values`` return the LIVE
    backing lists so the legacy attributes stay exact aliases, not
    copies."""

    kind = "series"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._items: List[Tuple[Optional[str], float]] = []
        self._values: List[float] = []

    def append(self, value: float, label: Optional[str] = None) -> None:
        self._items.append((label, value))
        self._values.append(value)

    def __len__(self) -> int:
        return len(self._items)

    @property
    def items(self) -> List[Tuple[Optional[str], float]]:
        return self._items

    @property
    def values(self) -> List[float]:
        return self._values

    def snapshot(self) -> Dict[str, Any]:
        by_label: Dict[str, Dict[str, float]] = {}
        for lab, v in self._items:
            d = by_label.setdefault(lab if lab is not None else "",
                                    {"count": 0, "sum": 0.0})
            d["count"] += 1
            d["sum"] += float(v)
        return {"type": self.kind, "count": len(self._items),
                "by_label": by_label}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "series": Series}


class MetricsRegistry:
    """Named metric store with get-or-create accessors and two export
    formats (Prometheus text exposition / JSON)."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help)
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {m.kind}"
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def series(self, name: str, help: str = "") -> Series:
        return self._get(Series, name, help)

    def names(self) -> Tuple[str, ...]:
        return tuple(self._metrics)

    # -- export -------------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {name: m.snapshot() for name, m in self._metrics.items()}

    def to_json(self, path: Optional[str] = None) -> str:
        txt = json.dumps(self.snapshot(), indent=1)
        if path:
            with open(path, "w") as f:
                f.write(txt + "\n")
        return txt

    def to_prometheus(self, path: Optional[str] = None) -> str:
        """Prometheus text exposition: counters/gauges verbatim,
        histograms as summaries (quantile labels + _sum/_count), series
        as per-label count/sum pairs."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            pn = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"# TYPE {pn} {m.kind}")
                lines.append(f"{pn} {m.value}")
            elif isinstance(m, Histogram):
                lines.append(f"# TYPE {pn} summary")
                for p, v in m.percentiles().items():
                    q = p / 100.0
                    lines.append(f'{pn}{{quantile="{q}"}} '
                                 f"{v if not math.isnan(v) else 'NaN'}")
                snap = m.snapshot()
                lines.append(f"{pn}_sum {snap['sum']}")
                lines.append(f"{pn}_count {snap['count']}")
            else:                                   # Series
                lines.append(f"# TYPE {pn} counter")
                snap = m.snapshot()
                for lab, d in snap["by_label"].items():
                    sel = f'{{label="{lab}"}}' if lab else ""
                    lines.append(f"{pn}_count{sel} {d['count']}")
                    lines.append(f"{pn}_sum{sel} {d['sum']}")
        txt = "\n".join(lines) + "\n"
        if path:
            with open(path, "w") as f:
                f.write(txt)
        return txt
