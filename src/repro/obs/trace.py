"""Host-side span tracer with Chrome-trace/Perfetto export (DESIGN.md §15).

One tracer serves the whole process: the Trainer's chunk
dispatch/execute/fetch phases, the Prefetcher's produce/wait pair (on its
worker thread), and the serving schedulers' tick phases all record into
it. Events live in host memory as plain tuples until ``export`` writes
the Chrome trace-event JSON (load the file at https://ui.perfetto.dev
or chrome://tracing).

Design constraints:

  * ONE wall-clock source. ``monotonic()`` (= ``time.perf_counter``) is
    the repo's only measurement clock — mixing ``time.time()`` into a
    perf_counter-based timeline made one-shot serve latencies and
    scheduler timestamps incomparable. Everything that stamps a duration
    or an arrival goes through this helper.
  * Near-zero overhead when disabled: ``span()`` on a disabled tracer
    returns a shared no-op context manager after a single attribute
    check — no object allocation, no clock read, no event
    (``tests/test_obs.py::test_disabled_tracer_costs_nothing``).
  * Zero device interaction. Recording touches only the clock and a
    list append, so instrumented code stays green under the
    ``analysis.hostsync`` guard; span ``args`` must already be host
    scalars (never jax arrays — stringifying one would sync).
  * Thread safety by construction: ``list.append`` is atomic under the
    GIL and each event carries its recording thread's id; export maps
    the ids to dense Perfetto track numbers with ``thread_name``
    metadata.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Tracer", "get_tracer", "monotonic", "set_tracer"]


def monotonic() -> float:
    """THE wall-clock of the repo: monotonic seconds (perf_counter).

    Not comparable across processes or to ``time.time()`` — durations
    and same-process orderings only, which is all the trainer, the
    schedulers, and the benchmarks ever need."""
    return time.perf_counter()


class _NullCtx:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self) -> "_NullCtx":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullCtx()


class _Span:
    """One open span; records a complete ('X') event on exit."""
    __slots__ = ("_tr", "_name", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, args: Dict[str, Any]):
        self._tr, self._name, self._args = tr, name, args

    def __enter__(self) -> "_Span":
        self._t0 = monotonic()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = monotonic()
        self._tr._record("X", self._name, self._t0, t1 - self._t0,
                         self._args)
        return False


def _jsonable(v: Any) -> Any:
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


class Tracer:
    """Nested spans + instant events on the monotonic clock.

    ``span(name, **args)`` is a context manager (nesting = call-stack
    containment, rendered as stacked slices per thread); ``instant``
    marks a point ('i' event, e.g. a jit retrace or a prefix-cache hit);
    ``counter`` records a 'C' series. ``export(path)`` writes
    ``{"traceEvents": [...]}`` with timestamps in µs since the tracer's
    epoch."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._epoch = monotonic()
        self._events: List[Tuple[str, str, float, float, int,
                                 Dict[str, Any]]] = []
        self._tid_names: Dict[int, str] = {}
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------------

    def _record(self, ph: str, name: str, ts: float, dur: float,
                args: Dict[str, Any]) -> None:
        tid = threading.get_ident()
        if tid not in self._tid_names:
            self._tid_names[tid] = threading.current_thread().name
        self._events.append((ph, name, ts, dur, tid, args))

    def span(self, name: str, **args):
        """Context manager timing the enclosed block. Disabled tracers
        return a shared no-op after one attribute check."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        self._record("i", name, monotonic(), 0.0, args)

    def counter(self, name: str, **values) -> None:
        if not self.enabled:
            return
        self._record("C", name, monotonic(), 0.0, values)

    # -- device-timeline hooks ---------------------------------------------

    def annotation(self, name: str):
        """Name the enclosed compiled dispatch on the device timeline
        (``jax.profiler.TraceAnnotation``) — only meaningful inside a
        ``jax.profiler`` window, free no-op otherwise."""
        if not self.enabled:
            return _NULL
        try:
            import jax.profiler
            return jax.profiler.TraceAnnotation(name)
        except Exception:   # profiler unavailable on exotic builds
            return _NULL

    def profile_window(self, logdir: Optional[str]):
        """Optional ``jax.profiler.trace`` window writing a TensorBoard-
        loadable device profile under ``logdir`` alongside this tracer's
        host spans."""
        if not self.enabled or not logdir:
            return _NULL
        import jax.profiler
        return jax.profiler.trace(logdir)

    # -- inspection / export ------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> List[Tuple[str, str, float, float, int,
                                   Dict[str, Any]]]:
        """Raw (ph, name, t_start, dur, tid, args) tuples, in record
        order (seconds on the monotonic clock)."""
        return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self._tid_names = {}
            self._epoch = monotonic()

    def export(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Chrome trace-event JSON object; written to ``path`` if given.

        Spans become complete ('X') events with ``ts``/``dur`` in µs;
        instants carry thread scope (``"s": "t"``); each thread gets a
        ``thread_name`` metadata event so Perfetto labels its track."""
        with self._lock:
            evs = list(self._events)
            names = dict(self._tid_names)
        dense: Dict[int, int] = {}
        for e in evs:
            dense.setdefault(e[4], len(dense))
        pid = os.getpid()
        out: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
             "args": {"name": "repro"}}]
        for tid, dt in dense.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": dt,
                        "args": {"name": names.get(tid, f"thread-{dt}")}})
        for ph, name, ts, dur, tid, args in evs:
            ev: Dict[str, Any] = {
                "ph": ph, "name": name, "pid": pid, "tid": dense[tid],
                "ts": (ts - self._epoch) * 1e6,
                "args": {k: _jsonable(v) for k, v in args.items()}}
            if ph == "X":
                ev["dur"] = dur * 1e6
            elif ph == "i":
                ev["s"] = "t"
            out.append(ev)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


# ---------------------------------------------------------------------------
# process-global tracer (disabled by default)
# ---------------------------------------------------------------------------
# Instrumented code paths (Trainer, Prefetcher, schedulers, dryrun) pick
# this up when no tracer is passed explicitly, so `--trace-out` in a
# launcher turns on every layer at once.

_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    global _GLOBAL
    _GLOBAL = tracer
    return tracer
