"""Pluggable collective-communication substrate for expert dispatch
(DESIGN.md §10): substrate registry + transports (`substrate.py`) and the
HLO-validated analytic bytes model (`cost.py`)."""
from repro.comm import cost  # noqa: F401  (must precede substrate)
from repro.comm.cost import (effective_chunks, ep_tier_groups,  # noqa: F401
                             factored_ep, format_table, layer_cost,
                             pipeline_time, step_cost, substrate_table,
                             transport_cost, transport_time)
from repro.comm.substrate import (CommConfig, CommEnv, Transport,  # noqa: F401
                                  available_substrates, comm_zero,
                                  dequantize, get_substrate, make_transport,
                                  quantize, register_substrate)
