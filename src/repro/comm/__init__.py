"""Pluggable collective-communication substrate for expert dispatch
(DESIGN.md §10): substrate registry + transports (`substrate.py`) and the
HLO-validated analytic bytes model (`cost.py`)."""
from repro.comm import cost  # noqa: F401  (must precede substrate)
from repro.comm.cost import (ep_tier_groups, factored_ep,  # noqa: F401
                             format_table, layer_cost, step_cost,
                             substrate_table, transport_cost)
from repro.comm.substrate import (CommConfig, CommEnv, Transport,  # noqa: F401
                                  available_substrates, comm_zero,
                                  dequantize, get_substrate, make_transport,
                                  quantize, register_substrate)
