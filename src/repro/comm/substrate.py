"""Pluggable collective-communication substrate (DESIGN.md §10).

The MoE dispatch/combine all-to-all — the one collective Gating Dropout
exists to avoid paying — used to be two inline ``jax.lax.all_to_all``
calls buried in ``core/moe.py::_routed_shard``: unmeasured, uncompressed,
and blind to network topology. This module makes the wire a first-class,
swappable component behind a registry (mirroring the §6 execution-backend
registry), selected by ``MoEConfig.comm`` (`CommConfig`):

  dense                   -- single-hop all-to-all over the full ep group
                             (bit-for-bit the historical inline path).
  hierarchical            -- two-hop exchange over a factored
                             ep = ep_inner x ep_outer group: an intra-tier
                             all-to-all (consecutive ranks = one machine/
                             node) followed by an inter-tier all-to-all
                             over strided groups. Delivers the SAME
                             permutation as dense (bitwise — asserted),
                             while turning each device's (ep - ep_inner)
                             cross-tier messages into (ep_outer - 1)
                             aggregated ones, the Shazeer-style
                             hierarchical dispatch.
  compressed              -- dense topology, payload quantized to int8 or
                             fp8 (e4m3) with one f32 scale per
                             (expert, capacity-slot) row; dequantized on
                             arrival. A custom VJP makes the backward wire
                             compressed too (straight-through estimator
                             through the rounding), so the routed path
                             still trains — Switch-Transformer-style
                             selective precision on the routed tensors.
  hierarchical_compressed -- both composed: quantize once, carry the int8
                             payload + scales through both hops,
                             dequantize once.
  overlapped[...]         -- any of the above, micro-chunked (DESIGN.md
                             §14): the (E, cap, d) payload splits into
                             ``CommConfig.n_chunks`` pieces along the
                             capacity axis and the chunks run through a
                             double-buffered software pipeline — the
                             dispatch of chunk i+1 and the combine of
                             chunk i-1 are issued around the expert FFN
                             of chunk i, so XLA's scheduler can hide
                             them behind the compute. The pipeline is an
                             UNROLLED Python loop (static chunk count):
                             the compiled HLO contains n_eff distinct
                             per-chunk collectives per hop, keeping the
                             telemetry == parsed-HLO invariant countable.
                             Each chunk is the same permutation its base
                             substrate performs and the expert FFN is
                             per-capacity-row independent, so the result
                             stays BITWISE-equal to the base substrate
                             (pinned in tests, like hierarchical).

Every substrate exposes the transport in two execution modes so the whole
matrix is testable on CPU:

  * ``dispatch``/``combine``   -- real collectives inside shard_map; the
                                  two-hop substrate factors a single mesh
                                  axis via ``axis_index_groups``
                                  (`parallel/sharding.py::ep_tier_groups`)
                                  or, for the ep_on_model layout, uses the
                                  (model, data) mesh axes AS the tiers.
  * ``vdispatch``/``vcombine`` -- the oracle backend's virtual emulation:
                                  identical permutation algebra as pure
                                  transposes over the stacked
                                  (ep, E, cap, d) tensor, factored axes
                                  and all.

Telemetry: ``Transport.telemetry`` returns the layer's exact all-to-all
call count / payload bytes / per-device wire bytes as in-graph constants,
computed from the SAME analytic model (`comm/cost.py`) that
``tests/test_comm.py`` validates against compiled-HLO collective counts —
counters, model, and executable cannot drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm import cost as C
from repro.comm.cost import ep_tier_groups, factored_ep
from repro.configs.base import CommConfig

__all__ = ["CommConfig", "CommEnv", "OverlappedTransport", "Transport",
           "available_substrates", "comm_zero", "get_substrate",
           "make_transport", "register_substrate"]


@dataclasses.dataclass(frozen=True)
class CommEnv:
    """Where a transport runs: the collective axis and its factorization.

    ``axis`` is the shard_map axis name (or tuple, for the ep_on_model
    layout) the exchange runs over; ``None`` selects the virtual
    (oracle) emulation. When the ep factorization is GIVEN by two mesh
    axes (ep_on_model: intra = model, inter = data), ``inner_axis``/
    ``outer_axis``/``inner_size`` name them and override
    ``CommConfig.ep_inner``."""
    ep: int
    axis: Any = None
    inner_axis: Optional[str] = None
    outer_axis: Optional[str] = None
    inner_size: int = 0


# ---------------------------------------------------------------------------
# quantization (compressed substrates)
# ---------------------------------------------------------------------------

_FP8_MAX = 448.0          # float8_e4m3fn finite max
_INT8_MAX = 127.0


def quantize(x: jax.Array, mode: str) -> Tuple[jax.Array, jax.Array]:
    """Per-row (last-dim) scaled quantization: (..., d) -> int8/fp8
    payload + one f32 scale per row. Zero rows get scale 1 so dequant is
    exact there."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    if mode == "fp8":
        scale = jnp.where(amax > 0, amax / _FP8_MAX, 1.0)
        q = jnp.clip(xf / scale, -_FP8_MAX, _FP8_MAX).astype(
            jnp.float8_e4m3fn)
    else:
        scale = jnp.where(amax > 0, amax / _INT8_MAX, 1.0)
        q = jnp.round(jnp.clip(xf / scale, -_INT8_MAX, _INT8_MAX)
                      ).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _compressed_pair(fwd_perm: Callable, bwd_perm: Callable, mode: str
                     ) -> Callable:
    """Wire transform ``dequant(perm(quant(x)))`` with a custom VJP:
    the cotangent takes the REVERSE permutation, also quantized (the
    backward all-to-all is compressed too), straight-through w.r.t. the
    rounding. ``perm`` must be a pure permutation (its linear transpose
    is its inverse), which every substrate's hop sequence is."""

    def _wire(perm, x):
        q, s = quantize(x, mode)
        return dequantize(perm(q), perm(s), x.dtype)

    @jax.custom_vjp
    def f(x):
        return _wire(fwd_perm, x)

    f.defvjp(lambda x: (_wire(fwd_perm, x), None),
             lambda _, g: (_wire(bwd_perm, g),))
    return f


# ---------------------------------------------------------------------------
# topologies (permutation algebra; payload-dtype agnostic)
# ---------------------------------------------------------------------------

def _a2a(buf, axis, split, concat, groups=None):
    return jax.lax.all_to_all(buf, axis, split_axis=split,
                              concat_axis=concat,
                              axis_index_groups=groups, tiled=True)


class _FlatTopo:
    """Single-hop all-to-all over the whole ep group."""

    def __init__(self, env: CommEnv):
        self.env = env
        self.tiers = None

    def dispatch(self, buf):                       # (E, cap, ...) per shard
        return _a2a(buf, self.env.axis, 0, 1)      # -> (E/ep, ep*cap, ...)

    def combine(self, buf):
        return _a2a(buf, self.env.axis, 1, 0)

    def vdispatch(self, bufs):                     # (ep, E, cap, ...)
        ep, E = bufs.shape[:2]
        b = bufs.reshape((ep, ep, E // ep) + bufs.shape[2:])
        b = jnp.moveaxis(b, 0, 2)                  # (dst, e_loc, src, cap,..)
        return b.reshape((E, ep * bufs.shape[2]) + bufs.shape[3:])

    def vcombine(self, buf):                       # (E, ep*cap, ...)
        ep = self.env.ep
        E = buf.shape[0]
        cap = buf.shape[1] // ep
        b = buf.reshape((ep, E // ep, ep, cap) + buf.shape[2:])
        b = jnp.moveaxis(b, 2, 0)                  # (src, dst, e_loc, cap,..)
        return b.reshape((ep, E, cap) + buf.shape[2:])


class _FactoredTopo:
    """Two-hop exchange over ep = ep_inner x ep_outer (rank = o*gi + i).

    Hop algebra (X[src][dst] = the chunk src holds for dst; src=(o,i)):
      intra:  A[(o,i)][o',i'] = X[(o,i')][o',i]     (tiers exchange inside)
      inter:  B[(o,i)][o2,i2] = A[(o2,i)][o ,i2]    (strided across tiers)
      =>      B[(o,i)][o2,i2] = X[(o2,i2)][o ,i ]   — exactly the flat a2a.
    Both hops are self-inverse tiled exchanges, so ``combine`` replays
    them in reverse order around the inverse reshape."""

    def __init__(self, comm: CommConfig, env: CommEnv):
        self.env = env
        if env.inner_axis is not None:             # tiers ARE mesh axes
            gi = env.inner_size
            go = env.ep // gi
            self.hops = ((env.inner_axis, None, 1),
                         (env.outer_axis, None, 0))
        else:                                      # factor one mesh axis
            gi, go = factored_ep(env.ep, comm.ep_inner)
            intra, inter = ep_tier_groups(env.ep, comm.ep_inner)
            self.hops = ((env.axis, [list(g) for g in intra], 1),
                         (env.axis, [list(g) for g in inter], 0))
        self.tiers = (gi, go)

    def _exchange(self, b, reverse=False):
        for axis, groups, ax in (reversed(self.hops) if reverse
                                 else self.hops):
            b = _a2a(b, axis, ax, ax, groups)
        return b

    def dispatch(self, buf):                       # (E, cap, ...) per shard
        E, cap = buf.shape[:2]
        gi, go = self.tiers
        e_loc = E // self.env.ep
        b = buf.reshape((go, gi, e_loc) + buf.shape[1:])
        b = self._exchange(b)                      # axes -> (o_src, i_src,..)
        b = jnp.moveaxis(b, 2, 0)                  # (e_loc, o_src, i_src,..)
        return b.reshape((e_loc, self.env.ep * cap) + buf.shape[2:])

    def combine(self, buf):                        # (e_loc, ep*cap, ...)
        gi, go = self.tiers
        e_loc = buf.shape[0]
        cap = buf.shape[1] // self.env.ep
        b = buf.reshape((e_loc, go, gi, cap) + buf.shape[2:])
        b = jnp.moveaxis(b, 0, 2)                  # (go, gi, e_loc, cap, ..)
        b = self._exchange(b, reverse=True)
        return b.reshape((self.env.ep * e_loc, cap) + buf.shape[2:])

    # virtual emulation: the same two hops as stacked-axis swaps
    def vdispatch(self, bufs):                     # (ep, E, cap, ...)
        gi, go = self.tiers
        ep, E, cap = bufs.shape[:3]
        e_loc = E // ep
        b = bufs.reshape((go, gi, go, gi, e_loc) + bufs.shape[2:])
        b = b.swapaxes(1, 3)                       # intra hop
        b = b.swapaxes(0, 2)                       # inter hop
        # axes now (o_dst, i_dst, o_src, i_src, e_loc, cap, ...)
        b = jnp.moveaxis(b, 4, 2)                  # (o_d, i_d, e_loc, o_s,..)
        return b.reshape((E, ep * cap) + bufs.shape[3:])

    def vcombine(self, buf):                       # (E, ep*cap, ...)
        gi, go = self.tiers
        ep = self.env.ep
        E = buf.shape[0]
        cap = buf.shape[1] // ep
        b = buf.reshape((go, gi, E // ep, go, gi, cap) + buf.shape[2:])
        b = jnp.moveaxis(b, 2, 4)                  # (o_d, i_d, o_s, i_s, e,..)
        b = b.swapaxes(0, 2)                       # undo inter hop
        b = b.swapaxes(1, 3)                       # undo intra hop
        return b.reshape((ep, E, cap) + buf.shape[2:])


# ---------------------------------------------------------------------------
# transport = topology (+ optional compression) + telemetry
# ---------------------------------------------------------------------------

class Transport:
    """One routed layer's wire. ``dispatch``: per-shard (E, cap, d) ->
    (E/ep, ep*cap, d); ``combine`` is the exact inverse; ``vdispatch``/
    ``vcombine`` are the oracle's stacked-tensor emulation
    (ep, E, cap, d) <-> (E, ep*cap, d). ``roundtrip`` applies only the
    payload wire transform (quant->dequant, no movement) — the ep=1
    kernel pipeline uses it so backend choice never changes numerics."""

    def __init__(self, comm: CommConfig, env: CommEnv, topo):
        self.comm, self.env, self.topo = comm, env, topo
        if comm.compressed:
            q = comm.quant
            self.dispatch = _compressed_pair(topo.dispatch, topo.combine, q)
            self.combine = _compressed_pair(topo.combine, topo.dispatch, q)
            self.vdispatch = _compressed_pair(topo.vdispatch,
                                              topo.vcombine, q)
            self.vcombine = _compressed_pair(topo.vcombine,
                                             topo.vdispatch, q)
            self.roundtrip = _compressed_pair(lambda x: x, lambda x: x, q)
        else:
            self.dispatch = topo.dispatch
            self.combine = topo.combine
            self.vdispatch = topo.vdispatch
            self.vcombine = topo.vcombine
            self.roundtrip = lambda x: x

    def pipelined(self, buf: jax.Array, fn: Callable) -> jax.Array:
        """The §14 transport contract: run ``dispatch -> fn -> combine``
        as ONE transaction, with the grouped-FFN body handed in as a
        per-chunk callable so overlapped substrates can interleave its
        chunks with the wire. Non-overlapped substrates are the trivial
        one-chunk case."""
        return self.combine(fn(self.dispatch(buf)))

    def vpipelined(self, bufs: jax.Array, fn: Callable) -> jax.Array:
        """``pipelined`` over the oracle's stacked (ep, E, cap, d)
        virtual emulation."""
        return self.vcombine(fn(self.vdispatch(bufs)))

    def telemetry(self, n_experts: int, cap: int, d_model: int,
                  itemsize: int) -> Dict[str, jax.Array]:
        """In-graph (constant) telemetry for one layer's transport —
        the §10/§14 counters, straight from the analytic model.
        ``comm_exposed_bytes``/``comm_hidden_bytes`` split the wire into
        the structurally non-overlappable fraction (the pipeline's edge
        chunks) and the remainder a chunked schedule can hide behind
        expert compute; non-overlapped substrates expose everything."""
        c = C.transport_cost(self.comm, ep=self.env.ep, n_experts=n_experts,
                             cap=cap, d_model=d_model, itemsize=itemsize,
                             tiers=self.topo.tiers)
        return {"comm_a2a_calls": jnp.asarray(c["calls"], jnp.float32),
                "comm_bytes": jnp.asarray(c["bytes"], jnp.float32),
                "comm_wire_bytes": jnp.asarray(c["wire_bytes"],
                                               jnp.float32),
                "comm_exposed_bytes": jnp.asarray(c["exposed_wire_bytes"],
                                                  jnp.float32),
                "comm_hidden_bytes": jnp.asarray(c["hidden_wire_bytes"],
                                                 jnp.float32)}


class OverlappedTransport(Transport):
    """Micro-chunked pipeline over any base topology (DESIGN.md §14).

    ``pipelined`` splits the (E, cap, d) payload into
    ``effective_chunks(cap, n_chunks)`` slices along the capacity axis
    and issues, per chunk i: dispatch(i+1) — prefetching the next
    chunk's wire — then FFN(i), then combine(i) (which overlaps
    FFN(i+1) on the next iteration). The loop is UNROLLED over the
    static chunk count so each per-chunk collective is a distinct HLO op
    (a lax.scan body would be counted once by the HLO walker, breaking
    the telemetry == parsed-HLO invariant) and so XLA's latency-hiding
    scheduler is free to slide the collectives behind the grouped
    matmuls.

    Bitwise equality with the base substrate holds because (a) each
    chunk undergoes the exact permutation the base substrate applies —
    dense's dispatched axis-1 layout is (src_rank, cap), so chunk i is
    precisely the [:, i*cc:(i+1)*cc] capacity slice of every source's
    block — (b) the expert FFN is independent per capacity row, and
    (c) the compressed pair's quantization scales are per (expert, slot)
    row, so quantizing chunkwise equals quantizing once then slicing."""

    def _n_chunks(self, cap: int) -> int:
        return C.effective_chunks(cap, self.comm.n_chunks)

    def pipelined(self, buf: jax.Array, fn: Callable) -> jax.Array:
        n = self._n_chunks(buf.shape[1])
        if n == 1:
            return self.combine(fn(self.dispatch(buf)))
        cc = buf.shape[1] // n
        chunks = [buf[:, i * cc:(i + 1) * cc] for i in range(n)]
        disp = [None] * n
        outs = [None] * n
        disp[0] = self.dispatch(chunks[0])
        for i in range(n):
            if i + 1 < n:                  # prefetch next chunk's wire
                disp[i + 1] = self.dispatch(chunks[i + 1])
            y = fn(disp[i])
            outs[i] = self.combine(y)      # overlaps FFN(i+1)
        return jnp.concatenate(outs, axis=1)

    def vpipelined(self, bufs: jax.Array, fn: Callable) -> jax.Array:
        n = self._n_chunks(bufs.shape[2])
        if n == 1:
            return self.vcombine(fn(self.vdispatch(bufs)))
        cc = bufs.shape[2] // n
        chunks = [bufs[:, :, i * cc:(i + 1) * cc] for i in range(n)]
        disp = [None] * n
        outs = [None] * n
        disp[0] = self.vdispatch(chunks[0])
        for i in range(n):
            if i + 1 < n:
                disp[i + 1] = self.vdispatch(chunks[i + 1])
            y = fn(disp[i])
            outs[i] = self.vcombine(y)
        return jnp.concatenate(outs, axis=2)


def comm_zero() -> Dict[str, jax.Array]:
    """Telemetry of a step that moves nothing (Gate-Drop local /
    expert-drop / dense-FFN layers)."""
    return {"comm_a2a_calls": jnp.zeros((), jnp.float32),
            "comm_bytes": jnp.zeros((), jnp.float32),
            "comm_wire_bytes": jnp.zeros((), jnp.float32),
            "comm_exposed_bytes": jnp.zeros((), jnp.float32),
            "comm_hidden_bytes": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# registry (mirrors core/backend.py)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[CommConfig, CommEnv], Transport]] = {}


def register_substrate(name: str):
    """Decorator: add a communication substrate under ``name``."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def available_substrates() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_substrate(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown comm substrate {name!r}; available: "
            f"{', '.join(available_substrates())}") from None


def make_transport(comm: CommConfig, env: CommEnv) -> Transport:
    """Build the configured substrate's transport for one layer trace."""
    return get_substrate(comm.substrate)(comm, env)


@register_substrate("dense")
def _dense(comm: CommConfig, env: CommEnv) -> Transport:
    return Transport(comm, env, _FlatTopo(env))


@register_substrate("hierarchical")
def _hierarchical(comm: CommConfig, env: CommEnv) -> Transport:
    return Transport(comm, env, _FactoredTopo(comm, env))


@register_substrate("compressed")
def _compressed(comm: CommConfig, env: CommEnv) -> Transport:
    return Transport(comm, env, _FlatTopo(env))


@register_substrate("hierarchical_compressed")
def _hierarchical_compressed(comm: CommConfig, env: CommEnv) -> Transport:
    return Transport(comm, env, _FactoredTopo(comm, env))


@register_substrate("overlapped")
def _overlapped(comm: CommConfig, env: CommEnv) -> Transport:
    return OverlappedTransport(comm, env, _FlatTopo(env))


@register_substrate("overlapped_hierarchical")
def _overlapped_hierarchical(comm: CommConfig, env: CommEnv) -> Transport:
    return OverlappedTransport(comm, env, _FactoredTopo(comm, env))


@register_substrate("overlapped_compressed")
def _overlapped_compressed(comm: CommConfig, env: CommEnv) -> Transport:
    return OverlappedTransport(comm, env, _FlatTopo(env))


@register_substrate("overlapped_hierarchical_compressed")
def _overlapped_hierarchical_compressed(comm: CommConfig,
                                        env: CommEnv) -> Transport:
    return OverlappedTransport(comm, env, _FactoredTopo(comm, env))
