"""Analytic bytes model for the communication substrate (DESIGN.md §10).

THE single source of truth for "how many bytes does a routed MoE layer
move": the in-graph telemetry counters (`comm/substrate.py`) are computed
FROM these functions, and `tests/test_comm.py` plus the lint suite's
no-collectives pass (`analysis/passes.py`) pin both against the
collective ops parsed out of compiled HLO (`analysis/hlo.py::
parse_collectives`), so the three views — counters in the metrics stream,
this model, and the executable itself — cannot drift apart.

Conventions (chosen to match ``parse_collectives`` exactly):

  * ``bytes``       -- sum over all-to-all ops of the per-device RESULT
                       bytes (an a2a preserves element count, so this is
                       also the per-device send buffer size).
  * ``wire_bytes``  -- per-device traffic actually crossing the wire:
                       ``bytes * (g - 1) / g`` per op for an a2a over a
                       group of ``g`` (a device keeps its own chunk).
  * ``calls``       -- number of all-to-all ops.

Pure host math — importing this module never touches jax device state.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from repro.analysis.hlo import DTYPE_BYTES
from repro.configs.base import CommConfig, ModelConfig

# wire itemsizes come from the ONE dtype table the HLO walker uses to
# size collectives, so the model can't disagree with the parser about
# what an int8/fp8 payload weighs (CommConfig.quant -> HLO dtype name)
_QUANT_ITEMSIZE = {"int8": DTYPE_BYTES["s8"], "fp8": DTYPE_BYTES["f8e4m3fn"]}
_SCALE_ITEMSIZE = DTYPE_BYTES["f32"]  # one scale per (expert, cap-slot) row


def factored_ep(ep: int, ep_inner: int = 0):
    """Factor an expert-parallel group of ``ep`` ranks into
    ``(ep_inner, ep_outer)`` tiers for the hierarchical substrate
    (DESIGN.md §10): rank r = outer * ep_inner + inner, i.e. consecutive
    ranks share a tier (machine/node), mirroring how pods enumerate chips.
    ``ep_inner == 0`` picks the largest divisor <= sqrt(ep), so the two
    hops are as square as possible. Re-exported by
    ``parallel/sharding.py`` next to the mesh partition rules."""
    if ep_inner == 0:
        ep_inner = max(g for g in range(1, int(math.isqrt(ep)) + 1)
                       if ep % g == 0)
    assert ep % ep_inner == 0, (ep, ep_inner)
    return ep_inner, ep // ep_inner


def ep_tier_groups(ep: int, ep_inner: int = 0):
    """``axis_index_groups`` for the two hierarchical hops over ONE mesh
    axis of size ``ep``: ``intra`` groups hold the ``ep_inner``
    consecutive ranks of each tier; ``inter`` groups hold the ranks with
    equal intra-tier index, strided by ``ep_inner`` — the member index
    within a group is the tier index, which the two-hop exchange algebra
    relies on."""
    gi, go = factored_ep(ep, ep_inner)
    intra = tuple(tuple(o * gi + i for i in range(gi)) for o in range(go))
    inter = tuple(tuple(o * gi + i for o in range(go)) for i in range(gi))
    return intra, inter


def effective_chunks(cap: int, n_chunks: int) -> int:
    """Micro-chunk count the overlapped transport ACTUALLY runs: the
    largest divisor of ``cap`` that is <= the requested ``n_chunks``
    (clamped to [1, cap]). Shared by the transport (comm/substrate.py)
    and this cost model so the two can never disagree about how many
    per-chunk collectives the executable contains (DESIGN.md §14)."""
    n = max(1, min(int(n_chunks), max(int(cap), 1)))
    while cap % n:
        n -= 1
    return n


def _a2a(elems: int, itemsize: int, g: int) -> Dict[str, float]:
    b = float(elems * itemsize)
    return {"calls": 1.0, "bytes": b, "wire_bytes": b * (g - 1) / max(g, 1)}


def _acc(total: Dict[str, float], op: Dict[str, float], tier: str) -> None:
    for k, v in op.items():
        total[k] += v
    total[f"{tier}_wire_bytes"] += op["wire_bytes"]


def transport_cost(comm: CommConfig, *, ep: int, n_experts: int, cap: int,
                   d_model: int, itemsize: int,
                   tiers: Optional[tuple] = None) -> Dict[str, float]:
    """Bytes/calls of ONE routed layer's transport (dispatch + combine)
    per device. ``itemsize`` is the activation dtype's wire width for the
    uncompressed payload; ``tiers`` (gi, go) overrides the hierarchical
    factorization when the mesh fixes it (ep_on_model: tiers are the
    (model, data) axes themselves). Keys: calls, bytes, wire_bytes,
    intra_wire_bytes, inter_wire_bytes, exposed_wire_bytes,
    hidden_wire_bytes. A flat substrate's single hop spans every tier, so
    ALL its wire counts as inter-tier — the pessimistic cross-machine
    bytes the paper targets; hierarchical substrates split the wire
    between the two tiers.

    Overlapped substrates run every hop ``n_eff`` times (one per
    capacity micro-chunk, ``effective_chunks``): ``calls`` multiplies by
    n_eff while ``bytes``/``wire_bytes`` stay EXACTLY equal to the one
    dense exchange (each chunk carries 1/n_eff of the rows — cap is
    divisible by n_eff by construction). ``exposed_wire_bytes`` is the
    structurally non-overlappable fraction: the pipeline's edge chunks
    (first dispatch, last combine) can never hide behind compute, so
    exposed = wire / n_eff and hidden = the rest; non-overlapped
    substrates expose everything (hidden = 0)."""
    rows = n_experts * cap
    elems = rows * d_model
    n_eff = effective_chunks(cap, comm.n_chunks) if comm.overlapped else 1
    total = {"calls": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
             "intra_wire_bytes": 0.0, "inter_wire_bytes": 0.0}
    # tensors crossing the wire per direction: [(elems, itemsize, name)]
    if comm.compressed:
        wire = [(elems, _QUANT_ITEMSIZE[comm.quant]),
                (rows, _SCALE_ITEMSIZE)]
    else:
        wire = [(elems, itemsize)]
    if comm.hierarchical:
        gi, go = tiers or factored_ep(ep, comm.ep_inner)
        hops = [(gi, "intra"), (go, "inter")]
    else:
        hops = [(ep, "inter")]
    # a group-of-1 exchange moves nothing and XLA deletes the op from the
    # executable — skip it so telemetry == HLO holds at ep=1 and for
    # degenerate hierarchical factorizations (prime ep -> ep_inner=1)
    hops = [(g, tier) for g, tier in hops if g > 1]
    for _direction in ("dispatch", "combine"):
        for g, tier in hops:
            for e, isz in wire:
                # n_eff per-chunk ops of e/n_eff elements each: the
                # integer division is exact (cap % n_eff == 0), so the
                # byte totals reproduce the unchunked exchange EXACTLY
                chunk_op = _a2a(e // n_eff, isz, g)
                _acc(total, {k: v * n_eff for k, v in chunk_op.items()},
                     tier)
    total["exposed_wire_bytes"] = total["wire_bytes"] / n_eff
    total["hidden_wire_bytes"] = (total["wire_bytes"]
                                  - total["exposed_wire_bytes"])
    return total


def routed_capacity(cfg: ModelConfig, tokens_per_shard: int, *,
                    is_training: bool = True) -> int:
    """Per-shard expert buffer capacity of a routed step — the same
    formula every backend uses (core/moe.py::_routed_shard)."""
    from repro.core.router import capacity
    moe = cfg.moe
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    return min(capacity(tokens_per_shard, moe.n_experts, moe.top_k, cf),
               tokens_per_shard)


def layer_cost(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
               comm: Optional[CommConfig] = None,
               is_training: bool = True) -> Dict[str, float]:
    """Transport cost of one routed MoE layer for a model config."""
    moe = cfg.moe
    assert moe is not None
    import numpy as np
    itemsize = np.dtype(cfg.dtype).itemsize
    return transport_cost(
        comm if comm is not None else moe.comm, ep=ep,
        n_experts=moe.n_experts,
        cap=routed_capacity(cfg, tokens_per_shard, is_training=is_training),
        d_model=cfg.d_model, itemsize=itemsize)


def step_cost(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
              comm: Optional[CommConfig] = None, is_training: bool = True,
              backward: bool = False) -> Dict[str, float]:
    """Transport cost of one ROUTED model step: ``layer_cost`` x the
    number of MoE layers; ``backward=True`` doubles everything (the VJP
    of every wire hop is the reverse hop — exact when ``remat`` is off;
    remat recomputes the forward inside the backward, adding one more
    forward's worth of collectives on top)."""
    from repro.training.steps import n_moe_layers
    per = layer_cost(cfg, tokens_per_shard=tokens_per_shard, ep=ep,
                     comm=comm, is_training=is_training)
    mult = n_moe_layers(cfg) * (2 if backward else 1)
    return {k: v * mult for k, v in per.items()}


def transport_time(cost: Dict[str, float], topology) -> Dict[str, float]:
    """Bandwidth-weighted two-tier wire time (DESIGN.md §14): intra-tier
    wire priced at the topology's ICI-class bandwidth, inter-tier at the
    DCN-class one. ``exposed_s``/``hidden_s`` split the total by the cost
    dict's structural exposed fraction. Pure math — never changes
    numerics, only estimates."""
    intra_s = cost["intra_wire_bytes"] / topology.intra_bps
    inter_s = cost["inter_wire_bytes"] / topology.inter_bps
    comm_s = intra_s + inter_s
    w = cost["wire_bytes"]
    frac = (cost.get("exposed_wire_bytes", w) / w) if w > 0 else 1.0
    return {"comm_s": comm_s, "exposed_s": comm_s * frac,
            "hidden_s": comm_s * (1.0 - frac)}


def pipeline_time(compute_s: float, comm_s: float, n_chunks: int) -> float:
    """Step time of the n-chunk double-buffered pipeline under a
    two-resource (network + compute) FIFO event model: dispatch(0) is
    issued first, then per chunk i the schedule issues dispatch(i+1),
    FFN(i) (after dispatch(i) lands), combine(i) (after FFN(i)) — the
    program order ``Transport.pipelined`` emits. Network ops serialize in
    issue order on one channel; compute on another. n_chunks=1 collapses
    to the serial comm + compute sum (nothing overlaps)."""
    n = max(1, int(n_chunks))
    if n == 1:
        return comm_s + compute_s
    hop_s = comm_s / (2 * n)           # one chunk's dispatch OR combine
    ffn_s = compute_s / n
    net = hop_s                        # dispatch(0) in flight
    d_done = [net] + [0.0] * (n - 1)
    cpu = 0.0
    for i in range(n):
        if i + 1 < n:
            net += hop_s
            d_done[i + 1] = net
        cpu = max(cpu, d_done[i]) + ffn_s          # FFN(i)
        net = max(net, cpu) + hop_s                # combine(i)
    return net


def substrate_table(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
                    is_training: bool = True, quant: str = "int8",
                    n_chunks: int = 0,
                    topology=None) -> Dict[str, Dict[str, float]]:
    """Predicted per-step forward bytes for EVERY registered substrate at
    a given factorization — the ``launch/dryrun.py --comm-table`` payload.
    Pure math: nothing is lowered or compiled (the registry import only
    defines transport builders). Each row also carries the two-tier time
    estimates ``t_comm_s``/``t_exposed_s`` (``transport_time`` at the
    config's — or the given — topology); ``n_chunks`` overrides the
    overlapped substrates' chunk count (0 keeps the config's)."""
    import dataclasses
    from repro.comm.substrate import available_substrates
    out = {}
    for name in available_substrates():
        comm = dataclasses.replace(
            cfg.moe.comm, substrate=name, quant=quant,
            n_chunks=n_chunks or cfg.moe.comm.n_chunks)
        c = step_cost(cfg, tokens_per_shard=tokens_per_shard,
                      ep=ep, comm=comm, is_training=is_training)
        t = transport_time(c, topology or comm.topology)
        c["t_comm_s"] = t["comm_s"]
        c["t_exposed_s"] = t["exposed_s"]
        out[name] = c
    return out


def format_table(table: Dict[str, Dict[str, float]]) -> str:
    """Human-readable substrate comparison (MiB per device per step);
    ``exp MiB`` is the structurally exposed (non-overlappable) wire and
    ``t_exp`` its two-tier bandwidth-weighted time (DESIGN.md §14)."""
    hdr = (f"{'substrate':<36}{'a2a':>5}{'bytes MiB':>12}"
           f"{'wire MiB':>11}{'inter MiB':>11}{'exp MiB':>10}"
           f"{'t_comm ms':>11}{'t_exp ms':>10}{'vs dense':>10}")
    lines = [hdr, "-" * len(hdr)]
    base = table.get("dense", {}).get("wire_bytes", 0.0) or math.inf
    for name, c in table.items():
        rel = c["wire_bytes"] / base if base else 0.0
        exposed = c.get("exposed_wire_bytes", c["wire_bytes"])
        t_comm = c.get("t_comm_s", 0.0) * 1e3
        t_exp = c.get("t_exposed_s", 0.0) * 1e3
        lines.append(
            f"{name:<36}{int(c['calls']):>5}{c['bytes']/2**20:>12.2f}"
            f"{c['wire_bytes']/2**20:>11.2f}"
            f"{c['inter_wire_bytes']/2**20:>11.2f}"
            f"{exposed/2**20:>10.2f}{t_comm:>11.3f}{t_exp:>10.3f}"
            f"{rel:>9.2f}x")
    return "\n".join(lines)
