"""Analytic bytes model for the communication substrate (DESIGN.md §10).

THE single source of truth for "how many bytes does a routed MoE layer
move": the in-graph telemetry counters (`comm/substrate.py`) are computed
FROM these functions, and `tests/test_comm.py` plus the lint suite's
no-collectives pass (`analysis/passes.py`) pin both against the
collective ops parsed out of compiled HLO (`analysis/hlo.py::
parse_collectives`), so the three views — counters in the metrics stream,
this model, and the executable itself — cannot drift apart.

Conventions (chosen to match ``parse_collectives`` exactly):

  * ``bytes``       -- sum over all-to-all ops of the per-device RESULT
                       bytes (an a2a preserves element count, so this is
                       also the per-device send buffer size).
  * ``wire_bytes``  -- per-device traffic actually crossing the wire:
                       ``bytes * (g - 1) / g`` per op for an a2a over a
                       group of ``g`` (a device keeps its own chunk).
  * ``calls``       -- number of all-to-all ops.

Pure host math — importing this module never touches jax device state.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from repro.analysis.hlo import DTYPE_BYTES
from repro.configs.base import CommConfig, ModelConfig

# wire itemsizes come from the ONE dtype table the HLO walker uses to
# size collectives, so the model can't disagree with the parser about
# what an int8/fp8 payload weighs (CommConfig.quant -> HLO dtype name)
_QUANT_ITEMSIZE = {"int8": DTYPE_BYTES["s8"], "fp8": DTYPE_BYTES["f8e4m3fn"]}
_SCALE_ITEMSIZE = DTYPE_BYTES["f32"]  # one scale per (expert, cap-slot) row


def factored_ep(ep: int, ep_inner: int = 0):
    """Factor an expert-parallel group of ``ep`` ranks into
    ``(ep_inner, ep_outer)`` tiers for the hierarchical substrate
    (DESIGN.md §10): rank r = outer * ep_inner + inner, i.e. consecutive
    ranks share a tier (machine/node), mirroring how pods enumerate chips.
    ``ep_inner == 0`` picks the largest divisor <= sqrt(ep), so the two
    hops are as square as possible. Re-exported by
    ``parallel/sharding.py`` next to the mesh partition rules."""
    if ep_inner == 0:
        ep_inner = max(g for g in range(1, int(math.isqrt(ep)) + 1)
                       if ep % g == 0)
    assert ep % ep_inner == 0, (ep, ep_inner)
    return ep_inner, ep // ep_inner


def ep_tier_groups(ep: int, ep_inner: int = 0):
    """``axis_index_groups`` for the two hierarchical hops over ONE mesh
    axis of size ``ep``: ``intra`` groups hold the ``ep_inner``
    consecutive ranks of each tier; ``inter`` groups hold the ranks with
    equal intra-tier index, strided by ``ep_inner`` — the member index
    within a group is the tier index, which the two-hop exchange algebra
    relies on."""
    gi, go = factored_ep(ep, ep_inner)
    intra = tuple(tuple(o * gi + i for i in range(gi)) for o in range(go))
    inter = tuple(tuple(o * gi + i for o in range(go)) for i in range(gi))
    return intra, inter


def _a2a(elems: int, itemsize: int, g: int) -> Dict[str, float]:
    b = float(elems * itemsize)
    return {"calls": 1.0, "bytes": b, "wire_bytes": b * (g - 1) / max(g, 1)}


def _acc(total: Dict[str, float], op: Dict[str, float], tier: str) -> None:
    for k, v in op.items():
        total[k] += v
    total[f"{tier}_wire_bytes"] += op["wire_bytes"]


def transport_cost(comm: CommConfig, *, ep: int, n_experts: int, cap: int,
                   d_model: int, itemsize: int,
                   tiers: Optional[tuple] = None) -> Dict[str, float]:
    """Bytes/calls of ONE routed layer's transport (dispatch + combine)
    per device. ``itemsize`` is the activation dtype's wire width for the
    uncompressed payload; ``tiers`` (gi, go) overrides the hierarchical
    factorization when the mesh fixes it (ep_on_model: tiers are the
    (model, data) axes themselves). Keys: calls, bytes, wire_bytes,
    intra_wire_bytes, inter_wire_bytes. A flat substrate's single hop
    spans every tier, so ALL its wire counts as inter-tier — the
    pessimistic cross-machine bytes the paper targets; hierarchical
    substrates split the wire between the two tiers."""
    rows = n_experts * cap
    elems = rows * d_model
    total = {"calls": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
             "intra_wire_bytes": 0.0, "inter_wire_bytes": 0.0}
    # tensors crossing the wire per direction: [(elems, itemsize, name)]
    if comm.compressed:
        wire = [(elems, _QUANT_ITEMSIZE[comm.quant]),
                (rows, _SCALE_ITEMSIZE)]
    else:
        wire = [(elems, itemsize)]
    if comm.hierarchical:
        gi, go = tiers or factored_ep(ep, comm.ep_inner)
        hops = [(gi, "intra"), (go, "inter")]
    else:
        hops = [(ep, "inter")]
    # a group-of-1 exchange moves nothing and XLA deletes the op from the
    # executable — skip it so telemetry == HLO holds at ep=1 and for
    # degenerate hierarchical factorizations (prime ep -> ep_inner=1)
    hops = [(g, tier) for g, tier in hops if g > 1]
    for _direction in ("dispatch", "combine"):
        for g, tier in hops:
            for e, isz in wire:
                _acc(total, _a2a(e, isz, g), tier)
    return total


def routed_capacity(cfg: ModelConfig, tokens_per_shard: int, *,
                    is_training: bool = True) -> int:
    """Per-shard expert buffer capacity of a routed step — the same
    formula every backend uses (core/moe.py::_routed_shard)."""
    from repro.core.router import capacity
    moe = cfg.moe
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    return min(capacity(tokens_per_shard, moe.n_experts, moe.top_k, cf),
               tokens_per_shard)


def layer_cost(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
               comm: Optional[CommConfig] = None,
               is_training: bool = True) -> Dict[str, float]:
    """Transport cost of one routed MoE layer for a model config."""
    moe = cfg.moe
    assert moe is not None
    import numpy as np
    itemsize = np.dtype(cfg.dtype).itemsize
    return transport_cost(
        comm if comm is not None else moe.comm, ep=ep,
        n_experts=moe.n_experts,
        cap=routed_capacity(cfg, tokens_per_shard, is_training=is_training),
        d_model=cfg.d_model, itemsize=itemsize)


def step_cost(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
              comm: Optional[CommConfig] = None, is_training: bool = True,
              backward: bool = False) -> Dict[str, float]:
    """Transport cost of one ROUTED model step: ``layer_cost`` x the
    number of MoE layers; ``backward=True`` doubles everything (the VJP
    of every wire hop is the reverse hop — exact when ``remat`` is off;
    remat recomputes the forward inside the backward, adding one more
    forward's worth of collectives on top)."""
    from repro.training.steps import n_moe_layers
    per = layer_cost(cfg, tokens_per_shard=tokens_per_shard, ep=ep,
                     comm=comm, is_training=is_training)
    mult = n_moe_layers(cfg) * (2 if backward else 1)
    return {k: v * mult for k, v in per.items()}


def substrate_table(cfg: ModelConfig, *, tokens_per_shard: int, ep: int,
                    is_training: bool = True,
                    quant: str = "int8") -> Dict[str, Dict[str, float]]:
    """Predicted per-step forward bytes for EVERY registered substrate at
    a given factorization — the ``launch/dryrun.py --comm-table`` payload.
    Pure math: nothing is lowered or compiled."""
    import dataclasses
    out = {}
    for name in ("dense", "hierarchical", "compressed",
                 "hierarchical_compressed"):
        comm = dataclasses.replace(cfg.moe.comm, substrate=name,
                                   quant=quant)
        out[name] = step_cost(cfg, tokens_per_shard=tokens_per_shard,
                              ep=ep, comm=comm, is_training=is_training)
    return out


def format_table(table: Dict[str, Dict[str, float]]) -> str:
    """Human-readable substrate comparison (MiB per device per step)."""
    hdr = (f"{'substrate':<26}{'a2a':>5}{'bytes MiB':>12}"
           f"{'wire MiB':>11}{'inter MiB':>11}{'vs dense':>10}")
    lines = [hdr, "-" * len(hdr)]
    base = table.get("dense", {}).get("wire_bytes", 0.0) or math.inf
    for name, c in table.items():
        rel = c["wire_bytes"] / base if base else 0.0
        lines.append(
            f"{name:<26}{int(c['calls']):>5}{c['bytes']/2**20:>12.2f}"
            f"{c['wire_bytes']/2**20:>11.2f}"
            f"{c['inter_wire_bytes']/2**20:>11.2f}{rel:>9.2f}x")
    return "\n".join(lines)
