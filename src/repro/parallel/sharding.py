"""Partition-spec derivation for params / optimizer state / batches / caches.

Axes (DESIGN.md §4):
  data  -- batch sharding AND expert parallelism (EP group == DP group)
  model -- tensor parallelism (heads, d_ff, vocab)
  pod   -- extra pure data parallelism (multi-pod)

Rules are name-based over the pytree paths produced by the model inits.
A dimension is sharded over an axis only when divisible by its size;
otherwise it is replicated on that axis (keeps every (arch x mesh)
combination lowerable, e.g. 25 heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.moe import ParallelContext


# Hierarchical-substrate mesh factorization (DESIGN.md §10): the ep
# group's tier structure and the axis_index_groups for its two hops live
# next to the rest of the partitioning rules. (Defined in comm/cost.py —
# the analytic bytes model consumes them too — and re-exported here.)
from repro.comm.cost import ep_tier_groups, factored_ep  # noqa: E402,F401

# Two-tier physical topology descriptor (DESIGN.md §14): maps the
# ep_inner tier onto intra-pod ICI-class links and the ep_outer tier
# onto inter-pod DCN-class links; CommConfig.topology carries one and
# comm/cost.py::transport_time prices the wire split against it.
# effective_chunks is the shared capacity->micro-chunk divisor rule the
# overlapped transport and the cost model must agree on.
from repro.comm.cost import effective_chunks  # noqa: E402,F401
from repro.configs.base import Topology  # noqa: E402,F401


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


class SpecBuilder:
    def __init__(self, cfg: ModelConfig, ctx: ParallelContext):
        self.cfg = cfg
        self.ctx = ctx
        self.mesh = ctx.mesh
        self.tp = ctx.tp_axis if ctx.tp_axis in self.mesh.axis_names else None
        self.ep = ctx.ep_axis
        self.dp = ctx.dp_axes  # ("pod","data") or ("data",)

    def div(self, axis, size: int):
        """axis if it divides size, else None."""
        if axis is None:
            return None
        return axis if size % _axis_size(self.mesh, axis) == 0 else None

    def fsdp(self, size: int):
        if not self.cfg.fsdp:
            return None
        return self.div(self.ep, size)

    # ---- parameter rules ---------------------------------------------------
    # keyed by (leaf name, in-experts?); each rule states its BASE ndim so a
    # stacked (per-segment) leaf with one extra leading repeats dim is
    # disambiguated correctly (e.g. expert w_in (E,d,f) vs dense w_in (d,f)).
    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        name = path[-1]
        in_experts = "experts" in path
        in_router = "router" in path
        b = self
        tp = self.tp
        if in_router:
            return P() if len(shape) <= 2 else P(None)

        def rule(name, in_experts):
            """-> (base_ndim, fn(shape)->P) or None"""
            if in_experts:
                if self.cfg.moe is not None and self.cfg.moe.ep_on_model \
                        and tp is not None:
                    eaxes = (self.ep, tp)   # EP over data x model, no TP
                    if name in ("w_in", "w_gate"):
                        return 3, lambda s: P(b.div(eaxes, s[0]), None, None)
                    if name == "w_out":
                        return 3, lambda s: P(b.div(eaxes, s[0]), None, None)
                    return None
                if name in ("w_in", "w_gate"):
                    return 3, lambda s: P(b.div(b.ep, s[0]), None, b.div(tp, s[2]))
                if name == "w_out":
                    return 3, lambda s: P(b.div(b.ep, s[0]), b.div(tp, s[1]), None)
                return None
            table = {
                "wq":   (3, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]), None)),
                "wk":   (3, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]), None)),
                "wv":   (3, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]), None)),
                "wo":   (3, lambda s: P(b.div(tp, s[0]), None, b.fsdp(s[2]))),
                "w_in": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_gate": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_out": (2, lambda s: P(b.div(tp, s[0]), b.fsdp(s[1]))),
                "w_dq": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_uq": (3, lambda s: P(None, b.div(tp, s[1]), None)),
                "w_dkv": (2, lambda s: P(b.fsdp(s[0]), None)),
                "w_ukv": (3, lambda s: P(None, b.div(tp, s[1]), None)),
                "w_z":  (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_x":  (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_B":  (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_C":  (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "w_dt": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "conv_w": (2, lambda s: P(None, b.div(tp, s[1]))),
                "embed": (2, lambda s: P(b.div(tp, s[0]), None)),
                "lm_head": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
                "img_proj": (2, lambda s: P(None, b.div(tp, s[1]))),
                "proj": (2, lambda s: P(b.fsdp(s[0]), b.div(tp, s[1]))),
            }
            return table.get(name)

        r = rule(name, in_experts)
        if r is None:
            return P()  # norms, scalars, biases, A_log, D, meta, ...
        base_ndim, fn = r
        if len(shape) == base_ndim:
            return fn(shape)
        if len(shape) == base_ndim + 1:       # stacked over segment repeats
            return P(None, *fn(shape[1:]))
        return P()

    # ---- cache rules -------------------------------------------------------
    def cache_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        """Cache leaves are stacked: (repeats, B, ...). Prefer batch sharding;
        fall back to sequence sharding over `data` for batch=1 decode."""
        name = path[-1]
        if name == "pos":                         # (repeats, W)
            return P()
        if len(shape) < 3:
            return P()
        bdim = shape[1]
        dp = self.dp if bdim % _axis_size(self.mesh, self.dp) == 0 else None
        if name in ("k", "v"):                    # (r, B, S, KV, hd)
            seq = None if dp is not None else self.div(self.ep, shape[2])
            return P(None, dp, seq, self.div(self.tp, shape[3]), None)
        if name == "c_kv":                        # (r, B, S, c)
            seq = None if dp is not None else self.div(self.ep, shape[2])
            return P(None, dp, seq, None)
        if name == "k_rope":                      # (r, B, S, dr)
            seq = None if dp is not None else self.div(self.ep, shape[2])
            return P(None, dp, seq, None)
        if name == "conv":                        # (r, B, k, ch)
            return P(None, dp, None, self.div(self.tp, shape[3]))
        if name == "h":                           # (r, B, H, P, N)
            return P(None, dp, self.div(self.tp, shape[2]), None, None)
        return P(None, dp) if dp else P()

    # ---- batch rules -------------------------------------------------------
    def batch_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        bdim = shape[0]
        dp = self.dp if bdim % _axis_size(self.mesh, self.dp) == 0 else None
        return P(dp, *([None] * (len(shape) - 1)))


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def tree_specs(tree_shape: Any, fn) -> Any:
    """Map (path names, shape) -> spec over a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_names(path), leaf.shape), tree_shape)


def param_specs(cfg: ModelConfig, ctx: ParallelContext, params_shape) -> Any:
    return tree_specs(params_shape, SpecBuilder(cfg, ctx).param_spec)


def state_specs(cfg: ModelConfig, ctx: ParallelContext, state_shape) -> Any:
    b = SpecBuilder(cfg, ctx)
    ps = tree_specs(state_shape["params"], b.param_spec)
    return {
        "params": ps,
        "opt": {
            "m": tree_specs(state_shape["opt"]["m"], b.param_spec),
            "v": tree_specs(state_shape["opt"]["v"], b.param_spec),
            "step": P(),
        },
        "step": P(),
    }


def batch_specs(cfg: ModelConfig, ctx: ParallelContext, batch_shape) -> Any:
    return tree_specs(batch_shape, SpecBuilder(cfg, ctx).batch_spec)


def cache_specs(cfg: ModelConfig, ctx: ParallelContext, cache_shape) -> Any:
    return tree_specs(cache_shape, SpecBuilder(cfg, ctx).cache_spec)


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
