from repro.parallel.sharding import (batch_specs, cache_specs, param_specs,
                                     state_specs, to_shardings, tree_specs)

__all__ = ["batch_specs", "cache_specs", "param_specs", "state_specs",
           "to_shardings", "tree_specs"]
