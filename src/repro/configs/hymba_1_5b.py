"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

[arXiv:2411.13676] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16; meta tokens; SWA except a few global layers.
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    rope_theta=10_000.0,
    max_seq=8192,
    sliding_window=1024,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=64),
    hybrid=HybridConfig(n_meta_tokens=128, global_attn_layers=(0, 15, 31)),
    source="arXiv:2411.13676",
)
