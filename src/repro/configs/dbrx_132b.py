"""dbrx-132b [moe] — 16 experts, top-4, fine-grained MoE.

[hf:databricks/dbrx-base] 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4. Gating Dropout applies (first-class).
"""
from repro.configs.base import GatingDropoutConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    rope_theta=500_000.0,
    max_seq=32_768,
    norm="layernorm",
    moe=MoEConfig(
        n_experts=16,
        top_k=4,
        d_ff_expert=10752,
        router_type="softmax",
        capacity_factor=1.25,
        moe_layer_period=1,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3),
    ),
    fsdp=True,
    source="hf:databricks/dbrx-base",
)
