"""yi-6b [dense] — llama-arch GQA.

[arXiv:2403.04652] 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5_000_000.0,
    max_seq=4096,
    source="arXiv:2403.04652",
)
