"""whisper-small [audio] — encoder-decoder with conv frontend (STUB).

[arXiv:2212.04356] 12L d_model=768 12H d_ff=3072 vocab=51865.
The mel-spectrogram + conv feature extractor is stubbed: input_specs()
provides precomputed frame embeddings (B, 1500, 768).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="encdec",
    n_layers=12,             # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    max_seq=4096,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    encdec=EncDecConfig(n_encoder_layers=12, encoder_seq=1500, frontend="stub"),
    source="arXiv:2212.04356",
)
