"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision] scaled per assignment:
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision encoder is a STUB: input_specs provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    max_seq=131_072,
    vlm=VLMConfig(cross_attn_period=5, n_image_tokens=1601, d_image=1280),
    fsdp=True,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scale per assignment)",
)
