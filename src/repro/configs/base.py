"""Config system for the repro framework.

Plain frozen dataclasses (hashable -> usable as jit static args).
Every assigned architecture file in this package exposes ``CONFIG`` built
from these dataclasses; ``repro.configs.registry`` maps arch-id -> config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Gating Dropout (the paper's contribution)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GatingDropoutConfig:
    """Gating Dropout (Liu et al., ICML 2022).

    mode:
      "off"              -- plain MoE baseline.
      "gate_drop"        -- with prob. `rate` route all tokens to the local
                            expert group, skipping the all-to-all.
      "gate_expert_drop" -- with prob. `rate` skip the MoE sub-layer entirely
                            (residual passthrough; LayerDrop-style).
    local_combine:
      "prob" -- dropped steps weight the local expert output by the
                renormalized local softmax (gate still gets gradient).
      "one"  -- weight 1.0 (strict "ignore the gating network").
    """
    mode: str = "off"                  # off | gate_drop | gate_expert_drop
    rate: float = 0.0                  # paper: 0.3 gate_drop, 0.2 gate_expert_drop
    local_combine: str = "prob"        # prob | one
    # Execution strategy: "traced_cond" (lax.cond in one executable) or
    # "host_cond" (two executables, drop-on one has NO all-to-all; paper-faithful).
    strategy: str = "traced_cond"

    def __post_init__(self):
        assert self.mode in ("off", "gate_drop", "gate_expert_drop"), self.mode
        assert self.local_combine in ("prob", "one")
        assert self.strategy in ("traced_cond", "host_cond")
        assert 0.0 <= self.rate <= 1.0

    @property
    def enabled(self) -> bool:
        return self.mode != "off" and self.rate > 0.0


# ---------------------------------------------------------------------------
# Communication substrate (DESIGN.md §10, §14)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Topology:
    """Two-tier interconnect descriptor (DESIGN.md §14): maps the
    hierarchical substrate's ep_inner/ep_outer tiers onto link classes so
    the cost model can price a simulated two-tier mesh. ``intra_gbps`` is
    the intra-tier (ICI / NVLink-class) per-device bandwidth in GB/s;
    ``inter_gbps`` the inter-tier (DCN / IB-class) bandwidth. Flat
    substrates span every tier, so ALL their wire is priced at
    ``inter_gbps`` — the pessimistic cross-machine rate."""
    intra_gbps: float = 400.0
    inter_gbps: float = 50.0

    def __post_init__(self):
        assert self.intra_gbps > 0 and self.inter_gbps > 0

    @property
    def intra_bps(self) -> float:
        return self.intra_gbps * 1e9

    @property
    def inter_bps(self) -> float:
        return self.inter_gbps * 1e9


COMM_SUBSTRATES = (
    "dense", "hierarchical", "compressed", "hierarchical_compressed",
    "overlapped", "overlapped_hierarchical", "overlapped_compressed",
    "overlapped_hierarchical_compressed")


@dataclass(frozen=True)
class CommConfig:
    """Collective-communication substrate for the MoE dispatch/combine path
    (comm/substrate.py registry, DESIGN.md §10, §14).

    substrate:
      "dense"                   -- single-hop all-to-all over the full ep
                                   group (the historical inline path).
      "hierarchical"            -- two-hop all-to-all over a factored
                                   ep = ep_inner x ep_outer group:
                                   intra-tier exchange first, then
                                   inter-tier — same permutation as dense
                                   (bitwise), 1/ep_inner the inter-tier
                                   message count.
      "compressed"              -- dense topology, payload quantized to
                                   ``quant`` with one f32 scale per
                                   (expert, slot) row; dequant on arrival;
                                   custom VJP (straight-through + the
                                   reverse wire also compressed) so the
                                   routed path still trains.
      "hierarchical_compressed" -- both.
      "overlapped[...]"         -- any of the above, micro-chunked along
                                   the capacity axis into ``n_chunks``
                                   pieces whose dispatch/combine
                                   collectives pipeline behind the expert
                                   FFN of the previous chunk (DESIGN.md
                                   §14). Same permutation per chunk, so
                                   bitwise-equal to its base substrate;
                                   the wire bytes are identical, only the
                                   EXPOSED (non-overlappable) fraction
                                   shrinks to 1/n_chunks.
    quant: wire dtype for compressed substrates: "int8" | "fp8"
      (float8_e4m3fn).
    ep_inner: intra-tier group size for hierarchical substrates (must
      divide ep); 0 = auto (largest divisor <= sqrt(ep)).
    n_chunks: requested micro-chunk count for overlapped substrates
      (actual count = largest divisor of the capacity <= n_chunks, see
      comm/cost.py::effective_chunks); ignored by non-overlapped ones.
    topology: two-tier bandwidth descriptor the cost model prices the
      wire with (pure-math time estimates only; never changes numerics).
    """
    substrate: str = "dense"
    quant: str = "int8"
    ep_inner: int = 0
    n_chunks: int = 4
    topology: Topology = field(default_factory=Topology)

    def __post_init__(self):
        assert self.substrate in COMM_SUBSTRATES, self.substrate
        assert self.quant in ("int8", "fp8"), self.quant
        assert self.ep_inner >= 0
        assert self.n_chunks >= 1, self.n_chunks

    @property
    def overlapped(self) -> bool:
        return self.substrate.startswith("overlapped")

    @property
    def hierarchical(self) -> bool:
        return "hierarchical" in self.substrate

    @property
    def compressed(self) -> bool:
        return self.substrate.endswith("compressed")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 1                      # paper default k=1 (Switch)
    d_ff_expert: int = 0                # 0 -> use model d_ff
    n_shared_experts: int = 0           # DeepSeek-style always-on experts
    router_type: str = "softmax"        # softmax | sigmoid | hash
    capacity_factor: float = 1.0        # train (paper); eval uses eval_capacity_factor
    eval_capacity_factor: float = 2.0
    jitter_eps: float = 0.01            # input jitter (Fedus et al.) on by default
    balance_coef: float = 0.01          # aux balance loss coefficient
    router_z_coef: float = 0.0          # optional router z-loss
    moe_layer_period: int = 1           # 1 = every layer; 2 = every other (paper)
    first_dense_layers: int = 0         # deepseek-v3: first 3 layers dense
    ep_on_model: bool = False           # beyond-paper: expert parallelism over
                                        # data x model (a2a bytes / tp; no TP
                                        # inside experts). Needs E % (dp*tp)==0.
    # Execution backend (core/backend.py registry, DESIGN.md §6):
    #   auto | oracle | sharded | pallas | pallas_fused (megakernel, §11)
    backend: str = "auto"
    # Collective-communication substrate for dispatch/combine (DESIGN.md §10)
    comm: CommConfig = field(default_factory=CommConfig)
    gating_dropout: GatingDropoutConfig = field(default_factory=GatingDropoutConfig)

    def __post_init__(self):
        assert self.backend in ("auto", "oracle", "sharded", "pallas",
                                "pallas_fused"), self.backend

    def d_ff(self, model_d_ff: int) -> int:
        return self.d_ff_expert or model_d_ff

    def is_moe_layer(self, layer_idx: int) -> bool:
        if layer_idx < self.first_dense_layers:
            return False
        return (layer_idx - self.first_dense_layers) % self.moe_layer_period == 0


# ---------------------------------------------------------------------------
# Attention variants
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2                     # d_inner = expand * d_model
    chunk: int = 64                     # SSD chunk length
    conv_kernel: int = 4
    n_groups: int = 1                   # B/C groups (GVA-style)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class VLMConfig:
    """Llama-3.2-Vision style gated cross-attention onto stub image embeds."""
    cross_attn_period: int = 5          # cross-attn layer every N layers
    n_image_tokens: int = 1601          # ViT stub output length (tokens)
    d_image: int = 1280                 # stub encoder width (projected to d_model)


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_seq: int = 1500             # whisper: 1500 frames post-conv
    frontend: str = "stub"              # conv frontend stubbed: input_specs gives embeds
    encoder_causal: bool = False


@dataclass(frozen=True)
class HybridConfig:
    """Hymba: parallel attention + SSM heads within each layer."""
    n_meta_tokens: int = 128
    # fraction of layers using global attention (rest SWA); hymba uses 3 global
    global_attn_layers: Tuple[int, ...] = (0, 15, 31)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "tiny"
    family: str = "dense"               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0                   # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    max_seq: int = 8192
    sliding_window: int = 0             # 0 = full attention
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"                   # silu (gated) | gelu (non-gated)
    gated_mlp: bool = True
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vlm: Optional[VLMConfig] = None
    encdec: Optional[EncDecConfig] = None
    hybrid: Optional[HybridConfig] = None
    mtp: bool = False                   # DeepSeek-V3 multi-token-prediction head
    dropout: float = 0.0
    dtype: str = "bfloat16"             # activation dtype
    param_dtype: str = "float32"
    remat: bool = True                  # checkpoint each layer
    fsdp: bool = False                  # shard weights over data axis too
    seq_parallel: bool = False          # shard layer-boundary activations
                                        # (sequence dim) over the model axis
    scan_layers: bool = True            # lax.scan over layer segments (fast
                                        # compile); False unrolls (exact
                                        # cost_analysis for the dry-run)
    banded_swa: bool = False            # sliding-window attention with block
                                        # skipping: O(L*W) instead of masked
                                        # O(L^2) (beyond-paper perf option)
    source: str = ""                    # citation for the config

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, dff, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            if self.mla is not None:
                m = self.mla
                attn = (d * m.q_lora_rank
                        + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.ssm is not None and self.family == "ssm":
                s = self.ssm
                di = s.d_inner(d)
                attn = d * (2 * di + 2 * s.n_groups * s.d_state + di // s.head_dim) + di * d
            mlp_mult = 3 if self.gated_mlp else 2
            if self.moe is not None and self.moe.is_moe_layer(i):
                dffe = self.moe.d_ff(dff)
                ffn = (self.moe.n_experts + self.moe.n_shared_experts) * mlp_mult * d * dffe
                ffn += self.moe.n_experts * d  # router
            else:
                ffn = mlp_mult * d * dff
            total += attn + ffn
        if self.encdec is not None:
            # encoder layers (honouring MoE period) + decoder cross-attn
            for i in range(self.encdec.n_encoder_layers):
                attn = 4 * d * d
                if self.moe is not None and self.moe.is_moe_layer(i):
                    dffe = self.moe.d_ff(dff)
                    ffn = (self.moe.n_experts + self.moe.n_shared_experts) * mlp_mult * d * dffe
                    ffn += self.moe.n_experts * d
                else:
                    ffn = mlp_mult * d * dff
                total += attn + ffn
            total += self.n_layers * 4 * d * d  # decoder cross attention
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k only), for MODEL_FLOPS."""
        if self.moe is None:
            return self.n_params()
        d, dff = self.d_model, self.d_ff
        mlp_mult = 3 if self.gated_mlp else 2
        dffe = self.moe.d_ff(dff)
        per_layer_all = (self.moe.n_experts) * mlp_mult * d * dffe
        per_layer_act = (self.moe.top_k + self.moe.n_shared_experts) * mlp_mult * d * dffe
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.moe.is_moe_layer(i))
        return self.n_params() - n_moe_layers * (per_layer_all - per_layer_act)


# ---------------------------------------------------------------------------
# Paged KV cache (serving, DESIGN.md §13)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PagedKVConfig:
    """Block-table-addressed decode cache (vLLM-style page pool).

    ``page_size`` logical positions per physical page (keep it a multiple
    of 8 — the paged flash kernel's KV block is one page). ``n_pages`` is
    the usable arena size (one extra scratch page is always appended);
    0 derives it from the slot pool it replaces: ``n_slots_equiv *
    ceil(seq_len / page_size)`` — equal paged-leaf KV bytes to an
    ``n_slots_equiv``-row slot pool. ``prefix_caching`` shares full
    prompt-prefix pages across requests via a token-hash page cache;
    ``reserve_pages`` is the admission headroom (a request is admitted
    only when its prompt pages + this reserve are free or evictable)."""
    page_size: int = 16
    n_pages: int = 0
    n_slots_equiv: int = 8
    prefix_caching: bool = True
    reserve_pages: int = 1

    def __post_init__(self):
        assert self.page_size >= 1
        assert self.reserve_pages >= 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 5000
    schedule: str = "inverse_sqrt"       # inverse_sqrt | cosine | constant
    b1: float = 0.9
    b2: float = 0.99                     # paper: beta2 = 0.99
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    seed: int = 0
    steps: int = 1000
    microbatches: int = 1                # grad accumulation: activation mem /k
    moment_dtype: str = "float32"        # bfloat16 for the huge archs
    loss: str = "xent"                   # xent | xent+dae (paper Web-50)
    dae_coef: float = 1.0
    # surface the in-graph router/comm MetricsFrame (DESIGN.md §15) in
    # every step's metric dict. Off drops ONLY telemetry outputs: the
    # loss/update math is bitwise identical either way (tests/test_obs.py)
    metrics_frame: bool = True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (<=2 layers, d<=512, <=4 experts)."""
    kw = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        d_ff=min(cfg.d_ff, 512) or 512,
        vocab=min(cfg.vocab, 512),
        max_seq=512,
        remat=False,
        fsdp=False,
        param_dtype="float32",
        dtype="float32",
    )
    n_heads = min(cfg.n_heads, 4)
    kw["n_heads"] = n_heads
    kw["n_kv_heads"] = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % kw["n_kv_heads"] != 0:
        kw["n_kv_heads"] -= 1
    kw["head_dim"] = kw["d_model"] // n_heads
    if cfg.sliding_window:
        kw["sliding_window"] = 128
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff(cfg.d_ff), 256),
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        kw["head_dim"] = 0
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk=16)
    if cfg.vlm is not None:
        kw["vlm"] = VLMConfig(cross_attn_period=2, n_image_tokens=16, d_image=64)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec, n_encoder_layers=2, encoder_seq=32)
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(n_meta_tokens=4, global_attn_layers=(0,))
        kw["ssm"] = SSMConfig(d_state=16, head_dim=32, expand=2, chunk=16)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
