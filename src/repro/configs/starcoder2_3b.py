"""starcoder2-3b [dense] — GQA, RoPE, sliding-window attention (4096).

[arXiv:2402.19173] 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    rope_theta=999_999.0,
    max_seq=16_384,
    sliding_window=4096,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    source="arXiv:2402.19173",
)
