"""The paper's own architectures (Kim et al. 2021 Z-code M3 baselines).

zcode-m3-base: Transformer-base MoE for WMT-10 — 12 enc + 6 dec layers,
d=512, 8H, d_ff=2048, 128 experts at every other FFN (~5.6B params).

zcode-m3-big: Transformer-big MoE for Web-50 — 24 enc + 12 dec layers,
d=1024, 16H, d_ff=4096, 64 experts (~10B params).

Both use top-1 (Switch) routing, capacity 1.0 train / 2.0 eval, input
jitter, balance coeff 0.01 — the paper's §4.1 settings.
"""
from repro.configs.base import (EncDecConfig, GatingDropoutConfig,
                                ModelConfig, MoEConfig)


def _moe(n_experts: int, gd_mode: str = "off", rate: float = 0.0) -> MoEConfig:
    return MoEConfig(
        n_experts=n_experts,
        top_k=1,
        router_type="softmax",
        capacity_factor=1.0,
        eval_capacity_factor=2.0,
        jitter_eps=0.01,
        balance_coef=0.01,
        moe_layer_period=2,          # every other FFN sub-layer (Fedus et al.)
        gating_dropout=GatingDropoutConfig(mode=gd_mode, rate=rate),
    )


CONFIG = ModelConfig(                 # zcode-m3-base (WMT-10)
    arch_id="zcode-m3-base",
    family="encdec",
    n_layers=6,                       # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=64_000,
    max_seq=1024,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    encdec=EncDecConfig(n_encoder_layers=12, encoder_seq=1024, frontend="tokens"),
    moe=_moe(128, "gate_drop", 0.3),
    source="Kim et al. 2021 (arXiv:2109.10465) / Liu et al. 2022 §4.1",
)

CONFIG_BIG = ModelConfig(             # zcode-m3-big (Web-50)
    arch_id="zcode-m3-big",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=128_000,
    max_seq=1024,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    encdec=EncDecConfig(n_encoder_layers=24, encoder_seq=1024, frontend="tokens"),
    moe=_moe(64, "gate_drop", 0.3),
    source="Kim et al. 2021 (arXiv:2109.10465) / Liu et al. 2022 §4.1",
)
