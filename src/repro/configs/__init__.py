"""Config registry: ``get_config(arch_id)`` / ``ARCHS``."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import (GatingDropoutConfig, InputShape, INPUT_SHAPES,
                                MLAConfig, ModelConfig, MoEConfig,
                                PagedKVConfig, SSMConfig, TrainConfig,
                                reduced)

_MODULES = {
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "yi-6b": "repro.configs.yi_6b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "whisper-small": "repro.configs.whisper_small",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "zcode-m3-base": "repro.configs.zcode_m3",
    "zcode-m3-big": "repro.configs.zcode_m3",
}

ARCHS = tuple(_MODULES)
ASSIGNED_ARCHS = ARCHS[:10]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    cfg = mod.CONFIG_BIG if arch_id.endswith("-big") else mod.CONFIG
    assert cfg.arch_id == arch_id, (cfg.arch_id, arch_id)
    return cfg


# Which (arch, shape) pairs are applicable. long_500k requires sub-quadratic
# attention (SWA / SSM / hybrid); decode shapes need a decoder.
_LONG_OK = {"starcoder2-3b", "h2o-danube-3-4b", "hymba-1.5b", "mamba2-1.3b"}


def shape_applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in _LONG_OK
    return True


def applicable_pairs():
    for a in ASSIGNED_ARCHS:
        for s in INPUT_SHAPES:
            if shape_applicable(a, s):
                yield a, s


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "INPUT_SHAPES", "InputShape", "GatingDropoutConfig",
    "MLAConfig", "ModelConfig", "MoEConfig", "PagedKVConfig", "SSMConfig",
    "TrainConfig", "applicable_pairs", "get_config", "reduced",
    "shape_applicable",
]
