"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA: kv heads == heads).

[hf:Qwen/CodeQwen1.5-7B] 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1_000_000.0,
    max_seq=65_536,
    source="hf:Qwen/CodeQwen1.5-7B",
)
