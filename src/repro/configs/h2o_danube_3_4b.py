"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    rope_theta=10_000.0,
    max_seq=8192,
    sliding_window=4096,
    source="arXiv:2401.16818",
)
