"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

[arXiv:2412.19437] 61L d_model=7168 128H d_ff=2048(expert) vocab=129280,
MoE 256e top-8, first 3 layers dense (dense d_ff=18432), sigmoid router.
Gating Dropout applies (first-class): the shared expert is local by
construction and never dropped; routed top-8 restricted to local group on
dropped steps.
"""
from repro.configs.base import GatingDropoutConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,           # MLA: kv heads == heads post-decompression
    d_ff=18432,               # dense layers' FFN width
    vocab=129280,
    rope_theta=10_000.0,
    max_seq=131_072,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        router_type="sigmoid",
        capacity_factor=1.25,
        moe_layer_period=1,
        first_dense_layers=3,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3),
    ),
    mtp=True,
    fsdp=True,
    dtype="bfloat16",
    source="arXiv:2412.19437",
)
