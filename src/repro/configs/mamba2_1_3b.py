"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060] 48L d_model=2048 vocab=50280 ssm_state=128.
d_inner = 2*d_model = 4096, head_dim=64 -> 64 SSD heads per layer.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,              # SSD heads (d_inner / head_dim)
    n_kv_heads=64,
    d_ff=0,                  # attention-free, no FFN (mamba block only)
    vocab=50280,
    max_seq=1_048_576,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128, conv_kernel=4),
    source="arXiv:2405.21060",
)
