"""Token-level BLEU proxy (corpus BLEU over token ids) + basic metrics.

The paper reports sacreBLEU on detokenized text; with synthetic token
data we compute standard corpus BLEU directly on id sequences — the
quantity plays the same role (n-gram overlap with the reference).
"""
from __future__ import annotations

import math
from collections import Counter
from typing import List, Sequence

import numpy as np


def _ngrams(seq: Sequence[int], n: int) -> Counter:
    return Counter(tuple(seq[i:i + n]) for i in range(len(seq) - n + 1))


def corpus_bleu(hyps: List[Sequence[int]], refs: List[Sequence[int]],
                max_n: int = 4) -> float:
    assert len(hyps) == len(refs)
    log_p = 0.0
    hyp_len = sum(len(h) for h in hyps)
    ref_len = sum(len(r) for r in refs)
    if hyp_len == 0:
        return 0.0
    for n in range(1, max_n + 1):
        match, total = 0, 0
        for h, r in zip(hyps, refs):
            hng, rng_ = _ngrams(h, n), _ngrams(r, n)
            match += sum(min(c, rng_[g]) for g, c in hng.items())
            total += max(len(h) - n + 1, 0)
        if total == 0:
            return 0.0
        # smoothed (add-eps) precision
        log_p += math.log((match + 1e-9) / (total + 1e-9))
    # sacreBLEU semantics: BP == 1 when hyp_len >= ref_len (the penalty
    # applies only to hypotheses STRICTLY shorter than the reference)
    bp = 1.0 if hyp_len >= ref_len else math.exp(1 - ref_len / hyp_len)
    return 100.0 * bp * math.exp(log_p / max_n)


def strip_special(seq: Sequence[int], eos: int = 2, pad: int = 0) -> List[int]:
    out = []
    for t in seq:
        if t == eos:
            break
        if t != pad:
            out.append(int(t))
    return out


def token_accuracy(pred: np.ndarray, labels: np.ndarray,
                   mask: np.ndarray) -> float:
    ok = (pred == labels) * mask
    return float(ok.sum() / max(mask.sum(), 1))
