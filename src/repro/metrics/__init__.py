from repro.metrics.bleu import corpus_bleu, strip_special, token_accuracy

__all__ = ["corpus_bleu", "strip_special", "token_accuracy"]
