"""Expert-parallel Mixture-of-Experts layer with Gating Dropout.

Layout (paper-faithful, DESIGN.md §4): expert parallelism over the `data`
mesh axis (EP group == DP group, as in Switch/DeepSpeed-MoE), tensor
parallelism of each expert's d_ff over the `model` axis (paper footnote 1),
pure extra data parallelism over `pod` (experts replicated across pods).

Numerically-identical implementations, selected via the execution-backend
registry (core/backend.py, DESIGN.md §6; ``MoEConfig.backend``):

  * ``moe_oracle``   -- pure jnp, `ep` *virtual* shards (vmap). Used on CPU,
                        in tests, and as the ground truth for the sharded path.
  * ``moe_sharded``  -- shard_map over the real mesh; the dispatch/combine
                        all-to-alls are explicit ``jax.lax.all_to_all`` over
                        the `data` axis.
  * ``pallas``       -- (backend.py) compiled kernel pipeline: fused routing
                        tables + scalar-prefetch gathers + grouped-FFN.

All share the same per-shard routing pieces, so equality is by
construction. Gating Dropout is a per-step global decision:

  routed step : route over all E experts -> dispatch -> a2a -> expert FFN
                -> a2a -> combine                           (all-to-all paid)
  gate_drop   : route restricted to the local expert group -> local dispatch
                -> local expert FFN -> combine              (no all-to-all)
  gate_expert_drop : output = 0 (residual passthrough)      (no a2a, no FFN)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import CommEnv, comm_zero, make_transport
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import router as R

Params = Dict[str, Any]
Decision = Union[None, bool, jax.Array]


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Mesh + axis-name bundle threaded through the model."""
    mesh: Optional[jax.sharding.Mesh] = None
    ep_axis: str = "data"     # expert parallel == data parallel (paper layout)
    tp_axis: str = "model"
    pod_axis: str = "pod"

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        if self.mesh is not None and self.pod_axis in self.mesh.axis_names:
            return (self.pod_axis, self.ep_axis)
        return (self.ep_axis,)

    @property
    def ep(self) -> int:
        return self.mesh.shape[self.ep_axis] if self.active else 1

    @property
    def tp(self) -> int:
        if self.active and self.tp_axis in self.mesh.axis_names:
            return self.mesh.shape[self.tp_axis]
        return 1


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_moe_params(key: jax.Array, cfg: ModelConfig, *, dtype=None) -> Params:
    moe = cfg.moe
    assert moe is not None
    d = cfg.d_model
    dff = moe.d_ff(cfg.d_ff)
    E = moe.n_experts
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    k_r, k_i, k_g, k_o = jax.random.split(key, 4)
    std_in = d ** -0.5
    std_out = dff ** -0.5
    p: Params = {
        "router": {"w": jax.random.normal(k_r, (d, E), dtype) * std_in},
        "experts": {
            "w_in": jax.random.normal(k_i, (E, d, dff), dtype) * std_in,
            "w_out": jax.random.normal(k_o, (E, dff, d), dtype) * std_out,
        },
    }
    if cfg.gated_mlp:
        p["experts"]["w_gate"] = jax.random.normal(k_g, (E, d, dff), dtype) * std_in
    return p


def moe_param_specs(cfg: ModelConfig, ctx: ParallelContext) -> Params:
    """PartitionSpec tree matching init_moe_params."""
    ep = ctx.ep_axis
    tp = ctx.tp_axis if (ctx.mesh is None
                         or ctx.tp_axis in ctx.mesh.axis_names) else None
    if cfg.moe is not None and cfg.moe.ep_on_model and tp is not None:
        # beyond-paper layout: experts sharded over data x model, no TP
        # inside experts (each expert's full d_ff lives on one device)
        ep, tp = (ep, tp), None
    specs: Params = {
        "router": {"w": P(None, None)},
        "experts": {
            "w_in": P(ep, None, tp),
            "w_out": P(ep, tp, None),
        },
    }
    if cfg.gated_mlp:
        specs["experts"]["w_gate"] = P(ep, None, tp)
    return specs


# ---------------------------------------------------------------------------
# per-shard pieces (shared by oracle and shard_map paths)
# ---------------------------------------------------------------------------

def _act(h: jax.Array, name: str) -> jax.Array:
    return jax.nn.silu(h) if name == "silu" else jax.nn.gelu(h)


def _expert_ffn(experts: Params, buf: jax.Array, cfg: ModelConfig,
                tp_axis: Optional[str]) -> jax.Array:
    """Apply per-expert FFN to (E_loc, C, d) buffers.

    Expert d_ff is sliced over `tp_axis`; the output matmul produces a
    partial sum that is reduced with psum (tensor parallelism inside each
    expert — the paper's footnote-1 tensor slicing). With kernels enabled
    the grouped matmuls run through the Pallas grouped_matmul kernel."""
    from repro.kernels import ops as K
    w_in = experts["w_in"]
    w_out = experts["w_out"]
    x = buf.astype(w_in.dtype)
    if K.KERNELS_ENABLED:
        y = K.expert_ffn_op(x, w_in, experts.get("w_gate"), w_out, cfg.act)
    else:
        h = jnp.einsum("ecd,edf->ecf", x, w_in)
        if cfg.gated_mlp:
            g = jnp.einsum("ecd,edf->ecf", x, experts["w_gate"])
            h = _act(g, cfg.act) * h
        else:
            h = _act(h, cfg.act)
        y = jnp.einsum("ecf,efd->ecd", h, w_out)
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y.astype(buf.dtype)


def _shard_rng(rng, my_shard):
    """Per-shard jitter key: fold the shard index so each 'machine' draws
    distinct routing noise (matches real per-worker noise)."""
    return None if rng is None else jax.random.fold_in(rng, my_shard)


def _routed_aux(rr, info, moe: MoEConfig, comm=None) -> Dict[str, jax.Array]:
    """Aux dict for a routed step — shared by every backend so metric
    semantics cannot desync (DESIGN.md §6). ``comm`` carries the layer's
    in-graph transport telemetry (DESIGN.md §10); None = no wire (ep=1
    kernel pipeline before the substrate is consulted)."""
    return {
        "balance": R.balance_loss(rr, moe) if moe.router_type != "hash"
                   else jnp.zeros(()),
        "router_z": R.router_z_loss(rr) if moe.router_type != "hash"
                    else jnp.zeros(()),
        "load": R.expert_load(rr, moe),
        "router_entropy": R.route_entropy(rr),
        "dropped_frac": 1.0 - info.keep.mean(),
        **(comm if comm is not None else comm_zero()),
    }


def _local_adjust(rr, moe: MoEConfig, lo, e_loc: int):
    """Gate-Drop local-path weight override + validity mask (shared)."""
    if moe.gating_dropout.local_combine == "one":
        rr = rr._replace(topk_w=jnp.full_like(rr.topk_w, 1.0 / moe.top_k))
    # entries that could not be satisfied locally (k > e_loc) are invalid
    valid = (rr.topk_idx >= lo) & (rr.topk_idx < lo + e_loc) & (rr.topk_w > 0)
    return rr, valid


def _local_aux(rr, info, moe: MoEConfig, T: int) -> Dict[str, jax.Array]:
    """Aux dict for a Gate-Drop local step (balance only on routed steps);
    ``rr`` must carry GLOBAL expert ids.

    Load counts ALL k slots, each weighted by ``info.keep`` (valid local
    pick that survived capacity) — matching the routed-step semantics of
    ``router.expert_load`` where ``load.sum() == top_k``; here the sum is
    <= top_k, short exactly by the dropped fraction. Counting only slot 0
    (the old behavior) misreported expert load for top_k > 1."""
    w = (info.keep.astype(jnp.float32) / T).reshape(-1)
    load = jnp.zeros((moe.n_experts,), jnp.float32).at[
        rr.topk_idx.reshape(-1)].add(w, mode="drop")
    return {"balance": jnp.zeros(()), "router_z": jnp.zeros(()),
            "load": load, "router_entropy": R.route_entropy(rr),
            "dropped_frac": 1.0 - info.keep.mean(),
            **comm_zero()}


def _token_valid_tk(token_valid, k: int):
    """(T,) bool token validity -> (T, k) dispatch validity (or None)."""
    if token_valid is None:
        return None
    return jnp.broadcast_to(token_valid.reshape(-1, 1),
                            (token_valid.size, k))


def _routed_shard(wr, experts, xf, moe: MoEConfig, cfg: ModelConfig, rng,
                  is_training, token_ids, my_shard, ep: int, tp_axis,
                  transport, token_valid=None):
    """Normal MoE step on one shard: route -> dispatch -> (wire) -> FFN ->
    (wire) -> combine. The wire is the configured comm substrate
    (``MoEConfig.comm``, DESIGN.md §10); ``dense`` is bit-for-bit the
    historical inline all-to-all pair. ``token_valid`` masks tokens
    (retired serving slots) out of capacity competition — they neither
    dispatch nor combine."""
    T = xf.shape[0]
    E = moe.n_experts
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    cap = min(R.capacity(T, E, moe.top_k, cf), T)
    rr = R.route(wr, xf, moe, rng=_shard_rng(rng, my_shard),
                 is_training=is_training, token_ids=token_ids)
    info = R.dispatch_info(rr, E, cap,
                           valid=_token_valid_tk(token_valid, moe.top_k))
    from repro.kernels import ops as K
    if K.KERNELS_ENABLED:
        # routing tables built once; the combine gather reuses them
        tables = K.routing_tables(info, E, cap)
        buf = K.moe_dispatch_op(xf, info, E, cap, tables=tables)
    else:
        tables = None
        buf = R.dispatch(xf, info, E, cap)                   # (E, cap, d)
    comm_t = transport.telemetry(E, cap, xf.shape[-1],
                                 jnp.dtype(buf.dtype).itemsize)
    # dispatch wire -> grouped FFN -> combine wire, as ONE transport
    # transaction (DESIGN.md §14): (E, cap, d) -> (E/ep, ep*cap, d) ->
    # FFN -> (E, cap, d). Overlapped substrates chunk the capacity axis
    # and pipeline the per-chunk collectives behind the FFN body.
    out = transport.pipelined(
        buf, lambda b: _expert_ffn(experts, b, cfg, tp_axis))
    y = (K.moe_combine_op(out, info, tables=tables) if K.KERNELS_ENABLED
         else R.combine(out, info))
    return y, _routed_aux(rr, info, moe, comm=comm_t)


def _local_shard(wr, experts_loc, xf, moe: MoEConfig, cfg: ModelConfig, rng,
                 is_training, token_ids, my_shard, ep: int, tp_axis,
                 token_valid=None):
    """Gate-Drop local step: tokens stay on this shard, routed among the
    local expert group only. No collective over the data axis."""
    T = xf.shape[0]
    E = moe.n_experts
    e_loc = E // ep
    lo = my_shard * e_loc
    rr = R.route(wr, xf, moe, rng=_shard_rng(rng, my_shard),
                 is_training=is_training, token_ids=token_ids,
                 expert_lo=lo, n_local=e_loc)
    rr, valid = _local_adjust(rr, moe, lo, e_loc)
    if token_valid is not None:
        valid = valid & token_valid.reshape(-1, 1)
    rr_local = rr._replace(topk_idx=rr.topk_idx - lo)
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    cap = min(R.capacity(T, e_loc, moe.top_k, cf), T)
    info = R.dispatch_info(rr_local, e_loc, cap, valid=valid)
    buf = R.dispatch(xf, info, e_loc, cap)                   # (e_loc, cap, d)
    out = _expert_ffn(experts_loc, buf, cfg, tp_axis)
    y = R.combine(out, info)
    return y, _local_aux(rr, info, moe, T)


def _zero_aux(E: int):
    return {"balance": jnp.zeros(()), "router_z": jnp.zeros(()),
            "load": jnp.zeros((E,), jnp.float32),
            "router_entropy": jnp.zeros(()),
            "dropped_frac": jnp.zeros(()), **comm_zero()}


# ---------------------------------------------------------------------------
# oracle (pure jnp, virtual shards)
# ---------------------------------------------------------------------------

def moe_oracle(params: Params, x: jax.Array, cfg: ModelConfig, *,
               ep: int = 1, rng: Optional[jax.Array] = None,
               decision: Decision = None, is_training: bool = True,
               token_ids: Optional[jax.Array] = None,
               token_valid: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict]:
    """Reference MoE with `ep` virtual machines. x: (B, L, d) or (T, d)."""
    moe = cfg.moe
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    assert T % ep == 0 and moe.n_experts % ep == 0
    xs = xf.reshape(ep, T // ep, shape[-1])
    tok = None if token_ids is None else token_ids.reshape(ep, T // ep)
    tv = None if token_valid is None else token_valid.reshape(ep, T // ep)
    wr = params["router"]["w"]
    experts = params["experts"]
    E = moe.n_experts

    def routed():
        Tl = T // ep
        cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
        cap = min(R.capacity(Tl, E, moe.top_k, cf), Tl)
        transport = make_transport(moe.comm, CommEnv(ep=ep))

        def shard_dispatch(my, xl, tl, tvl):
            rr = R.route(wr, xl, moe, rng=_shard_rng(rng, my),
                         is_training=is_training, token_ids=tl)
            info = R.dispatch_info(rr, E, cap,
                                   valid=_token_valid_tk(tvl, moe.top_k))
            return R.dispatch(xl, info, E, cap), info, rr

        bufs, infos, rrs = jax.vmap(
            shard_dispatch, in_axes=(0, 0, 0 if tok is not None else None,
                                     0 if tv is not None else None))(
            jnp.arange(ep), xs, tok, tv)
        # virtual wire (substrate emulation), one pipelined transaction:
        # (ep, E, cap, d) -> (E, ep*cap, d) -> FFN -> (ep, E, cap, d)
        outs = transport.vpipelined(
            bufs, lambda b: _expert_ffn(experts, b, cfg, None))
        y = jax.vmap(R.combine)(outs, infos)
        aux = {
            "balance": jax.vmap(lambda r: R.balance_loss(r, moe))(rrs).mean()
                       if moe.router_type != "hash" else jnp.zeros(()),
            "router_z": jax.vmap(R.router_z_loss)(rrs).mean()
                        if moe.router_type != "hash" else jnp.zeros(()),
            "load": jax.vmap(lambda r: R.expert_load(r, moe))(rrs).mean(0),
            "router_entropy": jax.vmap(R.route_entropy)(rrs).mean(),
            "dropped_frac": 1.0 - infos.keep.mean(),
            **transport.telemetry(E, cap, shape[-1],
                                  jnp.dtype(x.dtype).itemsize),
        }
        return y.reshape(ep * (T // ep), -1), aux

    def local():
        e_loc = E // ep

        def shard_local(my, xl, tl, tvl):
            ex_loc = jax.tree.map(lambda w: jax.lax.dynamic_slice_in_dim(
                w, my * e_loc, e_loc, axis=0), experts)
            return _local_shard(wr, ex_loc, xl, moe, cfg, rng, is_training,
                                tl, my, ep, None, token_valid=tvl)

        ys, auxs = jax.vmap(
            shard_local, in_axes=(0, 0, 0 if tok is not None else None,
                                  0 if tv is not None else None))(
            jnp.arange(ep), xs, tok, tv)
        return ys.reshape(T, -1), jax.tree.map(lambda a: a.mean(0), auxs)

    def expert_drop():
        return jnp.zeros((T, shape[-1]), x.dtype), _zero_aux(E)

    y, aux = _select_branch(moe, decision, routed, local, expert_drop)
    return y.reshape(shape), aux


def _select_branch(moe: MoEConfig, decision: Decision, routed, local,
                   expert_drop):
    """Pick the routed / dropped branch. Python-bool decision -> static
    branch (host_cond strategy: the collective is absent from the dropped
    executable). Traced decision -> lax.cond (traced_cond strategy)."""
    dropped = local if moe.gating_dropout.mode != "gate_expert_drop" else expert_drop
    if decision is None or (isinstance(decision, bool) and not decision):
        return routed()
    if isinstance(decision, bool):
        return dropped()
    return jax.lax.cond(decision, dropped, routed)


# ---------------------------------------------------------------------------
# shard_map (real mesh)
# ---------------------------------------------------------------------------

def moe_sharded(params: Params, x: jax.Array, cfg: ModelConfig,
                ctx: ParallelContext, *, rng: Optional[jax.Array] = None,
                decision: Decision = None, is_training: bool = True,
                token_ids: Optional[jax.Array] = None,
                token_valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict]:
    """MoE with real all-to-all over ctx.ep_axis. x: (B, L, d)."""
    moe = cfg.moe
    mesh = ctx.mesh
    E = moe.n_experts
    dp = ctx.dp_axes
    all_axes = tuple(mesh.axis_names)
    # beyond-paper layout (DESIGN.md §4): EP over data x model.
    # Each device holds E/(dp*tp) whole experts (full d_ff); tokens are
    # additionally sequence-sharded over `model`, so the all-to-all moves
    # 1/tp of the baseline bytes per device and the redundant
    # replicated-over-model dispatch disappears.
    ep_on_model = (moe.ep_on_model and ctx.tp > 1
                   and E % (ctx.ep * ctx.tp) == 0
                   and x.shape[1] % ctx.tp == 0)
    if ep_on_model:
        ep = ctx.ep * ctx.tp
        tp_axis = None
        # the ep group IS the (data x model) axis pair: hierarchical
        # substrates use those axes as the two tiers (model = intra)
        env = CommEnv(ep=ep, axis=(ctx.ep_axis, ctx.tp_axis),
                      inner_axis=ctx.tp_axis, outer_axis=ctx.ep_axis,
                      inner_size=ctx.tp)
        x_spec = P(dp, ctx.tp_axis, None)
        tok_spec = P(dp, ctx.tp_axis)
    else:
        ep = ctx.ep
        tp_axis = ctx.tp_axis if ctx.tp > 1 else None
        env = CommEnv(ep=ep, axis=ctx.ep_axis)
        x_spec = P(dp, None, None)
        tok_spec = P(dp, None)
    assert E % ep == 0, (E, ep)
    transport = make_transport(moe.comm, env)

    # Python-bool / None decisions are baked into the executable (host_cond):
    # the dropped executable contains no all-to-all. Traced decisions are
    # passed as a replicated operand (traced_cond).
    static_dec = decision if (decision is None or isinstance(decision, bool)) \
        else None
    traced = static_dec is None and decision is not None

    def body(wr, experts, x_loc, rng_, dec, tok_loc, tv_loc):
        B_loc, L, d = x_loc.shape
        xf = x_loc.reshape(B_loc * L, d)
        tf = None if tok_loc is None else tok_loc.reshape(-1)
        tvf = None if tv_loc is None else tv_loc.reshape(-1)
        if ep_on_model:
            my = (jax.lax.axis_index(ctx.ep_axis) * ctx.tp
                  + jax.lax.axis_index(ctx.tp_axis))
        else:
            my = jax.lax.axis_index(ctx.ep_axis)

        def routed():
            return _routed_shard(wr, experts, xf, moe, cfg, rng_, is_training,
                                 tf, my, ep, tp_axis, transport,
                                 token_valid=tvf)

        def local():
            return _local_shard(wr, experts, xf, moe, cfg, rng_, is_training,
                                tf, my, ep, tp_axis, token_valid=tvf)

        def expert_drop():
            return jnp.zeros_like(xf), _zero_aux(E)

        y, aux = _select_branch(moe, dec, routed, local, expert_drop)
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(B_loc, L, d), aux

    in_specs = [
        P(),                                   # router weights: replicated
        moe_param_specs(cfg, ctx)["experts"],  # experts: EP (+TP) layout
        x_spec,                                # x: batch over (pod,) data
        P(),                                   # rng
    ]
    args = [params["router"]["w"], params["experts"], x,
            rng if rng is not None else jax.random.PRNGKey(0)]
    if traced:
        in_specs.append(P())
        args.append(jnp.asarray(decision))
    if token_ids is not None:
        in_specs.append(tok_spec)
        args.append(token_ids)
    if token_valid is not None:
        in_specs.append(tok_spec)
        args.append(token_valid)

    def wrapper(*ops):
        wr, experts, x_loc, rng_ = ops[:4]
        i = 4
        if traced:
            dec = ops[i]; i += 1
        else:
            dec = static_dec
        tok_loc = None
        if token_ids is not None:
            tok_loc = ops[i]; i += 1
        tv_loc = ops[i] if token_valid is not None else None
        return body(wr, experts, x_loc, rng_, dec, tok_loc, tv_loc)

    fn = _shard_map(wrapper, mesh, tuple(in_specs), (x_spec, P()))
    return fn(*args)


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (experimental module pre-0.6)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def moe_apply(params: Params, x: jax.Array, cfg: ModelConfig,
              ctx: Optional[ParallelContext] = None, *,
              rng: Optional[jax.Array] = None, decision: Decision = None,
              is_training: bool = True,
              token_ids: Optional[jax.Array] = None,
              token_valid: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Dict]:
    """Entry point used by the models. The execution path is chosen by
    ``cfg.moe.backend`` through the backend registry (DESIGN.md §6);
    the default "auto" keeps the historical behavior — sharded when a real
    mesh is active, oracle otherwise. ``token_valid`` (same leading shape
    as ``x``'s token dims) marks tokens from retired/empty serving slots:
    they are routed but never dispatched, so they cannot steal expert
    capacity from live tokens (DESIGN.md §9)."""
    from repro.core import backend as B
    fn = B.get_backend(B.resolve_backend(cfg.moe, ctx))
    return fn(params, x, cfg, ctx, rng=rng, decision=decision,
              is_training=is_training, token_ids=token_ids,
              token_valid=token_valid)
