"""MoE execution-backend registry (DESIGN.md §6).

One MoE layer, three interchangeable execution paths, selected by
``MoEConfig.backend``:

  oracle  -- pure-jnp vmap over virtual shards; ground truth. Runs anywhere.
  sharded -- shard_map over a real mesh; dispatch/combine are explicit
             ``jax.lax.all_to_all`` collectives (the path Gating Dropout
             skips on dropped steps).
  pallas  -- the compiled kernel pipeline: routing tables built ONCE per
             step (kernels.ops.routing_tables), then scalar-prefetch
             dispatch gather -> grouped-matmul expert FFN -> weighted
             combine gather. interpret mode auto-detected per platform.
  auto    -- (default) sharded when a real mesh is active, oracle otherwise
             — the historical moe_apply behavior.

New fast paths register here (``@register_backend("name")``) and become
selectable via config + one parity test, instead of forking moe.py. All
backends share the router (core/router.py) and the Gating Dropout branch
selection (core/moe.py), so parity is by construction up to kernel
numerics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import router as R

Params = Dict[str, Any]
# fn(params, x, cfg, ctx, *, rng, decision, is_training, token_ids)
BackendFn = Callable[..., Tuple[jax.Array, Dict]]

_REGISTRY: Dict[str, BackendFn] = {}


def register_backend(name: str) -> Callable[[BackendFn], BackendFn]:
    """Decorator: add an execution backend under ``name``."""
    def deco(fn: BackendFn) -> BackendFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> BackendFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown MoE backend {name!r}; available: "
                       f"{', '.join(available_backends())}") from None


def resolve_backend(moe: MoEConfig, ctx) -> str:
    """'auto' -> 'sharded' iff a real (multi-device) mesh is active."""
    name = moe.backend
    if name == "auto":
        return "sharded" if (ctx is not None and ctx.active) else "oracle"
    return name


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

@register_backend("oracle")
def oracle_backend(params: Params, x: jax.Array, cfg: ModelConfig, ctx=None,
                   **kw) -> Tuple[jax.Array, Dict]:
    """Pure-jnp ground truth (single virtual shard)."""
    from repro.core.moe import moe_oracle
    return moe_oracle(params, x, cfg, ep=1, **kw)


@register_backend("sharded")
def sharded_backend(params: Params, x: jax.Array, cfg: ModelConfig, ctx=None,
                    **kw) -> Tuple[jax.Array, Dict]:
    """shard_map + explicit all_to_all. Without a mesh in ctx, a 1-axis
    mesh over every visible device is built (so the path is exercised —
    and parity-testable — even on a single-device host)."""
    from repro.core.moe import ParallelContext, moe_sharded
    from repro.launch.mesh import make_mesh
    if ctx is None or ctx.mesh is None:
        ctx = ParallelContext(mesh=make_mesh((jax.device_count(),), ("data",)))
    return moe_sharded(params, x, cfg, ctx, **kw)


@register_backend("pallas")
def pallas_backend(params: Params, x: jax.Array, cfg: ModelConfig, ctx=None,
                   *, rng: Optional[jax.Array] = None, decision=None,
                   is_training: bool = True,
                   token_ids: Optional[jax.Array] = None,
                   token_valid: Optional[jax.Array] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, Dict]:
    """Kernel pipeline: route -> routing_tables (once) -> dispatch gather
    -> grouped-FFN -> combine gather. Numerically matches the oracle at
    ep=1. With a real mesh active, expert parallelism composes by running
    the sharded path with the per-shard kernel pipeline enabled — the
    all-to-alls and per-shard routing noise stay exactly as `sharded`."""
    import contextlib
    from repro.core.moe import (_local_adjust, _local_aux, _routed_aux,
                                _select_branch, _shard_rng, _zero_aux)
    from repro.kernels import ops as K
    from repro.kernels.platform import force_interpret

    if ctx is not None and ctx.active:
        pin = (force_interpret(interpret) if interpret is not None
               else contextlib.nullcontext())
        with K.use_kernels(True), pin:
            return sharded_backend(params, x, cfg, ctx, rng=rng,
                                   decision=decision, is_training=is_training,
                                   token_ids=token_ids,
                                   token_valid=token_valid)

    from repro.comm import CommEnv, make_transport

    moe = cfg.moe
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    E = moe.n_experts
    tok = None if token_ids is None else token_ids.reshape(-1)
    tv = (None if token_valid is None
          else jnp.broadcast_to(token_valid.reshape(-1, 1), (T, moe.top_k)))
    wr = params["router"]["w"]
    experts = params["experts"]
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    cap = min(R.capacity(T, E, moe.top_k, cf), T)
    # ep=1 wire: no movement, but the payload transform (compressed
    # substrates' quant->dequant) still applies so backend choice never
    # changes numerics vs the oracle (DESIGN.md §10)
    transport = make_transport(moe.comm, CommEnv(ep=1))

    def _pipeline(info: R.DispatchInfo) -> jax.Array:
        tables = K.routing_tables(info, E, cap)    # built once, reused twice
        buf = K.dispatch(xf, tables.slot_token, tables.slot_valid,
                         interpret=interpret).reshape(E, cap, -1)
        buf = transport.roundtrip(buf)
        w_in = experts["w_in"]
        out = K.expert_ffn_op(buf.astype(w_in.dtype), w_in,
                              experts.get("w_gate"), experts["w_out"],
                              cfg.act, interpret=interpret)
        out = transport.roundtrip(out.astype(xf.dtype))
        return K.combine(out.reshape(E * cap, -1), tables.token_slot,
                         info.topk_w, info.keep, interpret=interpret)

    def routed():
        rr = R.route(wr, xf, moe, rng=_shard_rng(rng, 0),
                     is_training=is_training, token_ids=tok)
        info = R.dispatch_info(rr, E, cap, valid=tv)
        comm_t = transport.telemetry(E, cap, shape[-1],
                                     jnp.dtype(xf.dtype).itemsize)
        return _pipeline(info), _routed_aux(rr, info, moe, comm=comm_t)

    def local():
        # ep=1 Gate-Drop: the "local group" is all E experts (mirrors
        # moe.py::_local_shard with my_shard=0, e_loc=E), kernel-executed.
        rr = R.route(wr, xf, moe, rng=_shard_rng(rng, 0),
                     is_training=is_training, token_ids=tok,
                     expert_lo=0, n_local=E)
        rr, valid = _local_adjust(rr, moe, 0, E)
        if tv is not None:
            valid = valid & tv
        info = R.dispatch_info(rr, E, cap, valid=valid)
        return _pipeline(info), _local_aux(rr, info, moe, T)

    def expert_drop():
        return jnp.zeros((T, shape[-1]), x.dtype), _zero_aux(E)

    y, aux = _select_branch(moe, decision, routed, local, expert_drop)
    return y.reshape(shape), aux


@register_backend("pallas_fused")
def pallas_fused_backend(params: Params, x: jax.Array, cfg: ModelConfig,
                         ctx=None, *, rng: Optional[jax.Array] = None,
                         decision=None, is_training: bool = True,
                         token_ids: Optional[jax.Array] = None,
                         token_valid: Optional[jax.Array] = None,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jax.Array, Dict]:
    """ONE-launch megakernel pipeline (kernels.moe_megakernel, DESIGN.md
    §11): route -> fused gather + expert FFN + weighted scatter. Same
    router, same Gating Dropout branches, same aux as `pallas` — the
    (E, C, d) buffer and its two extra HBM roundtrips are gone, and the
    five per-layer kernel launches collapse to one.

    Falls back to the unfused `pallas` path when a real mesh is active
    (expert parallelism needs the materialized buffer on the wire) or when
    the comm substrate is compressed (the quant->dequant payload transform
    applies to that buffer); ep=1 dense/hierarchical wires are identity,
    so skipping them changes nothing (DESIGN.md §10)."""
    from repro.core.moe import (_local_adjust, _local_aux, _routed_aux,
                                _select_branch, _shard_rng, _zero_aux)
    from repro.kernels import ops as K

    moe = cfg.moe
    if (ctx is not None and ctx.active) or moe.comm.compressed:
        return pallas_backend(params, x, cfg, ctx, rng=rng, decision=decision,
                              is_training=is_training, token_ids=token_ids,
                              token_valid=token_valid, interpret=interpret)

    from repro.comm import CommEnv, make_transport

    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    E = moe.n_experts
    tok = None if token_ids is None else token_ids.reshape(-1)
    tv = (None if token_valid is None
          else jnp.broadcast_to(token_valid.reshape(-1, 1), (T, moe.top_k)))
    wr = params["router"]["w"]
    experts = params["experts"]
    cf = moe.capacity_factor if is_training else moe.eval_capacity_factor
    cap = min(R.capacity(T, E, moe.top_k, cf), T)
    # telemetry priced identically to `pallas` (ep=1 wire) so the aux dict
    # is backend-invariant; the identity roundtrip itself is fused away
    transport = make_transport(moe.comm, CommEnv(ep=1))

    def _pipeline(info: R.DispatchInfo) -> jax.Array:
        tables = K.routing_tables(info, E, cap)
        return K.fused_moe_op(xf, info, experts["w_in"],
                              experts.get("w_gate"), experts["w_out"],
                              E, cap, cfg.act, interpret=interpret,
                              tables=tables)

    def routed():
        rr = R.route(wr, xf, moe, rng=_shard_rng(rng, 0),
                     is_training=is_training, token_ids=tok)
        info = R.dispatch_info(rr, E, cap, valid=tv)
        comm_t = transport.telemetry(E, cap, shape[-1],
                                     jnp.dtype(xf.dtype).itemsize)
        return _pipeline(info), _routed_aux(rr, info, moe, comm=comm_t)

    def local():
        rr = R.route(wr, xf, moe, rng=_shard_rng(rng, 0),
                     is_training=is_training, token_ids=tok,
                     expert_lo=0, n_local=E)
        rr, valid = _local_adjust(rr, moe, 0, E)
        if tv is not None:
            valid = valid & tv
        info = R.dispatch_info(rr, E, cap, valid=valid)
        return _pipeline(info), _local_aux(rr, info, moe, T)

    def expert_drop():
        return jnp.zeros((T, shape[-1]), x.dtype), _zero_aux(E)

    y, aux = _select_branch(moe, decision, routed, local, expert_drop)
    return y.reshape(shape), aux
