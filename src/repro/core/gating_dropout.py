"""Gating Dropout (Liu et al., ICML 2022) — the paper's core mechanism.

At each training iteration, with probability ``rate`` ALL machines skip the
MoE all-to-all and route tokens to their machine-local experts (Gate-Drop)
or skip the MoE sub-layer entirely (Gate-Expert-Drop).

Consensus. The paper appoints a coordinator rank that draws the Bernoulli
and broadcasts one bit per step. On TPU/JAX we use *deterministic consensus*
instead: every host folds the (replicated) training step into the same PRNG
seed — identical inputs give identical draws on every host, so consensus
costs zero communication and is bitwise reproducible. Documented in
DESIGN.md §2.

Execution strategies:
  traced_cond -- one executable; ``jax.lax.cond`` on a traced decision bit.
  host_cond   -- two executables (routed / dropped); the host draws the bit
                 and dispatches. The dropped executable contains NO
                 all-to-all at all (asserted in tests). Paper-faithful.

Inference: decision is constant False (p=0); no weight rescaling is needed
because Gating Dropout alters routing, not activation magnitudes (paper §3).
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GatingDropoutConfig


def decision_key(seed: int, step: Union[int, jax.Array]) -> jax.Array:
    """The consensus PRNG key for a step (same on every host by construction)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x6A7E_D0), step)


def drop_decision(cfg: GatingDropoutConfig, seed: int,
                  step: Union[int, jax.Array], *,
                  is_training: bool = True) -> jax.Array:
    """Traced (or concrete) boolean: True => this step drops the all-to-all."""
    if not is_training or not cfg.enabled:
        return jnp.asarray(False)
    return jax.random.bernoulli(decision_key(seed, step), cfg.rate)


def drop_decision_host(cfg: GatingDropoutConfig, seed: int, step: int, *,
                       is_training: bool = True) -> bool:
    """Concrete python bool for the host_cond strategy (same draw as above)."""
    if not is_training or not cfg.enabled:
        return False
    return bool(jax.device_get(
        jax.random.bernoulli(decision_key(seed, step), cfg.rate)))


@jax.jit
def _decisions_batch(seed: jax.Array, steps: jax.Array,
                     rate: jax.Array) -> jax.Array:
    key = jax.random.PRNGKey(seed ^ 0x6A7E_D0)
    return jax.vmap(
        lambda s: jax.random.bernoulli(jax.random.fold_in(key, s), rate)
    )(steps)


def drop_decisions_host(cfg: GatingDropoutConfig, seed: int, start: int,
                        stop: int, *, is_training: bool = True) -> np.ndarray:
    """Concrete bools for steps [start, stop) in ONE jitted dispatch —
    bitwise the per-step ``drop_decision_host`` draws (same (seed, step)
    fold, vmapped). The scan-fused Trainer's host_cond path uses this so
    drawing a chunk's bits never costs per-step eager dispatches."""
    n = max(stop - start, 0)
    if not is_training or not cfg.enabled or n == 0:
        return np.zeros(n, bool)
    # explicit sync: drawing the chunk's bits host-side IS the strategy
    return jax.device_get(_decisions_batch(seed, jnp.arange(start, stop),
                                           cfg.rate))


def expected_alltoall_fraction(cfg: GatingDropoutConfig) -> float:
    """Fraction of steps that still pay the all-to-all: 1 - p (both modes)."""
    return 1.0 - (cfg.rate if cfg.enabled else 0.0)


def expected_expert_flop_fraction(cfg: GatingDropoutConfig) -> float:
    """Fraction of expert FLOPs still paid. Gate-Expert-Drop also skips the
    expert computation on dropped steps (paper §3.1)."""
    if cfg.mode == "gate_expert_drop" and cfg.enabled:
        return 1.0 - cfg.rate
    return 1.0
