"""Token -> expert routing: gating networks, top-k selection, capacity
dispatch/combine. Pure shard-local functions — used unchanged by both the
dense oracle (vmapped over virtual shards) and the shard_map MoE (per
device), so the two paths are numerically identical by construction.

Routers:
  softmax  -- Switch/GShard gating (paper's setting; jitter noise supported)
  sigmoid  -- DeepSeek-V3-style sigmoid scores, renormalized top-k
  hash     -- Hash-Layer baseline (Roller et al. 2021): fixed multiplicative
              hash of token ids; no learned gate, no balance-loss gradient.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

_HASH_MULT = 2654435761  # Knuth multiplicative hash


class RouteResult(NamedTuple):
    """Shard-local routing decision for T tokens."""
    topk_idx: jax.Array      # (T, k) int32 expert ids (global expert space)
    topk_w: jax.Array        # (T, k) combine weights
    probs: jax.Array         # (T, E) router probabilities (for balance loss)
    logits: jax.Array        # (T, E) raw logits (for z-loss)


class DispatchInfo(NamedTuple):
    pos: jax.Array           # (T, k) int32 position within expert buffer
    keep: jax.Array          # (T, k) bool: survived capacity
    topk_idx: jax.Array      # (T, k)
    topk_w: jax.Array        # (T, k)


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    import math
    return max(1, math.ceil(factor * n_tokens * top_k / n_experts))


def router_logits(wr: jax.Array, x: jax.Array, cfg: MoEConfig,
                  rng: Optional[jax.Array], is_training: bool) -> jax.Array:
    """(T, d) -> (T, E) logits; applies multiplicative input jitter in training."""
    if is_training and cfg.jitter_eps > 0.0 and rng is not None:
        lo, hi = 1.0 - cfg.jitter_eps, 1.0 + cfg.jitter_eps
        x = x * jax.random.uniform(rng, x.shape, x.dtype, lo, hi)
    return (x.astype(jnp.float32) @ wr.astype(jnp.float32))


def route(wr: jax.Array, x: jax.Array, cfg: MoEConfig, *,
          rng: Optional[jax.Array] = None, is_training: bool = True,
          token_ids: Optional[jax.Array] = None,
          expert_lo: int | jax.Array = 0,
          n_local: Optional[int] = None) -> RouteResult:
    """Route T tokens. If ``n_local`` is given, routing is RESTRICTED to the
    local expert group [expert_lo, expert_lo + n_local) — the Gating-Dropout
    local path: tokens ignore remote experts entirely.
    """
    E = cfg.n_experts
    T = x.shape[0]
    k = cfg.top_k
    logits = router_logits(wr, x, cfg, rng, is_training)

    if cfg.router_type == "hash":
        assert token_ids is not None, "hash router needs token ids"
        h = (token_ids.astype(jnp.uint32) * jnp.uint32(_HASH_MULT)) >> jnp.uint32(16)
        if n_local is None:
            idx0 = (h % jnp.uint32(E)).astype(jnp.int32)
        else:
            idx0 = (h % jnp.uint32(n_local)).astype(jnp.int32) + expert_lo
        topk_idx = idx0[:, None]  # hash router is inherently top-1
        if k > 1:  # spread extra slots deterministically
            extra = [(idx0 + 1 + j) % E for j in range(k - 1)]
            topk_idx = jnp.stack([idx0] + extra, axis=1).astype(jnp.int32)
        topk_w = jnp.full((T, k), 1.0 / k, dtype=jnp.float32)
        probs = jax.nn.one_hot(idx0, E, dtype=jnp.float32)
        return RouteResult(topk_idx, topk_w, probs, jax.lax.stop_gradient(logits))

    if n_local is not None:
        # mask logits outside the local group (Gate-Drop local path)
        eids = jnp.arange(E, dtype=jnp.int32)
        local = (eids >= expert_lo) & (eids < expert_lo + n_local)
        logits = jnp.where(local[None, :], logits, -jnp.inf)

    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        if n_local is not None:
            scores = jnp.where(jnp.isfinite(logits), scores, 0.0)
        topk_s, topk_idx = jax.lax.top_k(scores, k)
        topk_w = topk_s / jnp.maximum(topk_s.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:  # softmax (paper)
        probs = jax.nn.softmax(logits, axis=-1)
        topk_p, topk_idx = jax.lax.top_k(probs, k)
        if k > 1:
            topk_w = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
        else:
            topk_w = topk_p  # paper eq. (2): y = p_i(x) E_i(x)
    return RouteResult(topk_idx.astype(jnp.int32), topk_w, probs, logits)


def _positions_in_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each entry within its expert, in stable token order.

    Memory-light sort-based formulation (no (T*k, E) one-hot): O(Tk log Tk).
    """
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[flat_e[order]]
    return jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)


def dispatch_info(rr: RouteResult, n_experts: int, cap: int,
                  valid: Optional[jax.Array] = None) -> DispatchInfo:
    """Compute buffer positions. ``valid`` (T, k) masks entries that must not
    consume capacity (e.g. non-local picks on a Gate-Drop local step)."""
    T, k = rr.topk_idx.shape
    flat_e = rr.topk_idx.reshape(-1)
    if valid is not None:
        # phantom bucket n_experts for invalid entries
        flat_e = jnp.where(valid.reshape(-1), flat_e, n_experts)
        pos = _positions_in_expert(flat_e, n_experts + 1).reshape(T, k)
        keep = (pos < cap) & valid
    else:
        pos = _positions_in_expert(flat_e, n_experts).reshape(T, k)
        keep = pos < cap
    return DispatchInfo(pos=pos, keep=keep, topk_idx=rr.topk_idx, topk_w=rr.topk_w)


def dispatch(x: jax.Array, info: DispatchInfo, n_experts: int, cap: int,
             expert_lo: int | jax.Array = 0) -> jax.Array:
    """Scatter tokens (T, d) into expert buffers (n_experts, cap, d).

    ``expert_lo`` re-bases global expert ids into a local buffer (used by the
    Gate-Drop local path where the buffer covers only the local group).
    """
    T, k = info.topk_idx.shape
    d = x.shape[-1]
    keep = info.keep.reshape(-1)
    flat_e = jnp.where(keep, (info.topk_idx - expert_lo).reshape(-1), n_experts)
    flat_p = jnp.where(keep, info.pos.reshape(-1), cap)        # OOB -> dropped
    xk = jnp.broadcast_to(x[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((n_experts, cap, d), x.dtype)
    return buf.at[flat_e, flat_p].add(xk, mode="drop")


def combine(buf: jax.Array, info: DispatchInfo, *, weight_dtype=jnp.float32,
            expert_lo: int | jax.Array = 0) -> jax.Array:
    """Gather expert outputs back to token order with combine weights.

    buf: (n_experts, cap, d) -> (T, d)
    """
    T, k = info.topk_idx.shape
    keep = info.keep.reshape(-1)
    flat_e = jnp.where(keep, (info.topk_idx - expert_lo).reshape(-1), 0)
    flat_p = jnp.where(keep, info.pos.reshape(-1), 0)
    gathered = buf.at[flat_e, flat_p].get(mode="fill", fill_value=0)  # (T*k, d)
    gathered = gathered.reshape(T, k, -1)
    w = (info.topk_w * info.keep).astype(weight_dtype)
    return jnp.einsum("tkd,tk->td", gathered.astype(weight_dtype), w).astype(buf.dtype)


def balance_loss(rr: RouteResult, cfg: MoEConfig) -> jax.Array:
    """Switch/GShard auxiliary balance loss: E * sum_e f_e * P_e.

    f_e = fraction of tokens whose top-1 choice is e (non-differentiable),
    P_e = mean router probability of e. Minimized (=1) at uniform load.
    """
    E = cfg.n_experts
    top1 = rr.topk_idx[:, 0]
    f = jnp.zeros((E,), jnp.float32).at[top1].add(1.0) / top1.shape[0]
    p = rr.probs.mean(axis=0)
    return E * jnp.sum(jax.lax.stop_gradient(f) * p)


def router_z_loss(rr: RouteResult) -> jax.Array:
    lse = jax.scipy.special.logsumexp(rr.logits, axis=-1)
    return jnp.mean(lse ** 2)


def route_entropy(rr: RouteResult) -> jax.Array:
    """Mean per-token entropy (nats) of the router distribution — the
    routing-collapse monitor of the MetricsFrame (DESIGN.md §15):
    uniform routing gives log(E), collapse onto one expert gives 0. The
    hash router's one-hot probs report 0 by construction; Gate-Drop
    local steps report the entropy of the local-group distribution (the
    -inf-masked softmax is a proper distribution over the group)."""
    p = rr.probs
    return -jnp.sum(p * jnp.log(jnp.clip(p, 1e-20, None)), axis=-1).mean()


def expert_load(rr: RouteResult, cfg: MoEConfig) -> jax.Array:
    """(E,) routed assignments per expert over ALL k slots, per token
    (monitoring): ``load.sum() == top_k``. Gate-Drop local steps report the
    same quantity restricted to slots that survived locally
    (core/moe.py::_local_aux), so the two step kinds stay comparable."""
    f = jnp.zeros((cfg.n_experts,), jnp.float32).at[
        rr.topk_idx.reshape(-1)].add(1.0)
    return f / rr.topk_idx.shape[0]
