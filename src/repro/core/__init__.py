"""Core: the paper's contribution — Gating Dropout + expert-parallel MoE."""
from repro.core.gating_dropout import (decision_key, drop_decision,
                                       drop_decision_host,
                                       expected_alltoall_fraction,
                                       expected_expert_flop_fraction)
from repro.core.moe import (ParallelContext, init_moe_params, moe_apply,
                            moe_oracle, moe_param_specs, moe_sharded)
from repro.core.backend import (available_backends, get_backend,
                                register_backend, resolve_backend)
from repro.core import router

__all__ = [
    "ParallelContext", "available_backends", "decision_key", "drop_decision",
    "drop_decision_host", "expected_alltoall_fraction",
    "expected_expert_flop_fraction", "get_backend", "init_moe_params",
    "moe_apply", "moe_oracle", "moe_param_specs", "moe_sharded",
    "register_backend", "resolve_backend", "router",
]
