from repro.data.pipeline import (BOS, EOS, PAD, LMTaskConfig, MTTaskConfig,
                                 MultilingualMT, SyntheticLM)
from repro.data.prefetch import Prefetcher, stack_batches

__all__ = ["BOS", "EOS", "PAD", "LMTaskConfig", "MTTaskConfig",
           "MultilingualMT", "Prefetcher", "SyntheticLM", "stack_batches"]
