from repro.data.pipeline import (BOS, EOS, PAD, LMTaskConfig, MTTaskConfig,
                                 MultilingualMT, SyntheticLM)

__all__ = ["BOS", "EOS", "PAD", "LMTaskConfig", "MTTaskConfig",
           "MultilingualMT", "SyntheticLM"]
