"""Deterministic synthetic data pipelines.

The paper's task (multilingual MT on WMT-10 / Web-50) is not
redistributable, so we generate a *structured* synthetic analogue that
preserves the property Gating Dropout exploits: per-language structure
that experts can specialize on.

Multilingual MT task: each "language" l has a seeded token permutation
pi_l. A sample for direction (l_src -> l_tgt) is
    source  = [tag(l_tgt)] s_1..s_n [EOS]
    target  = reverse(pi_{l_tgt}(s))               (so the model must learn
                                                    a per-language mapping +
                                                    a global reordering rule)
Low-resource languages appear with small sampling weight — the Table-4
(low) split. Everything is a pure function of (seed, step, shard), so the
pipeline is reproducible and shards are disjoint by construction.

Batch synthesis is **vectorized** (DESIGN.md §8): each task draws all of a
batch's randomness up-front in a fixed order, then assembles the rows with
pure numpy array ops — no per-sample Python loop on the hot path. The
loop-based assembly survives as ``sample_batch_loop`` (the readable
reference consuming the exact same draws); ``tests/test_trainer.py``
asserts the two are equal element-for-element, so the vectorized path can
never silently drift.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2


@dataclass(frozen=True)
class MTTaskConfig:
    vocab: int = 512
    n_langs: int = 8
    low_resource_frac: float = 0.25   # last quarter of langs are low-resource
    low_resource_weight: float = 0.05
    src_len: Tuple[int, int] = (8, 24)
    max_len: int = 32
    seed: int = 1234
    dae_frac: float = 0.0             # fraction of DAE (denoising) samples


class MultilingualMT:
    """Deterministic multilingual translation generator."""

    def __init__(self, cfg: MTTaskConfig):
        self.cfg = cfg
        self.first_content = 3 + cfg.n_langs
        self.n_content = cfg.vocab - self.first_content
        assert self.n_content > 10, "vocab too small"
        root = np.random.default_rng(cfg.seed)
        self.perms = [root.permutation(self.n_content)
                      for _ in range(cfg.n_langs)]
        n_low = max(1, int(cfg.n_langs * cfg.low_resource_frac))
        w = np.ones(cfg.n_langs)
        w[-n_low:] = cfg.low_resource_weight
        self.lang_weights = w / w.sum()
        self.low_langs = list(range(cfg.n_langs - n_low, cfg.n_langs))
        # Zipf-ish content distribution
        ranks = np.arange(1, self.n_content + 1)
        zipf = 1.0 / ranks ** 1.1
        self.content_p = zipf / zipf.sum()

    def lang_tag(self, lang: int) -> int:
        return 3 + lang

    def train_batches(self, batch: int, **kw):
        """step -> model-ready batch: ``sample_batch`` minus the ``lang``
        key (per-sample metadata the jitted train step must not see).
        THE batch_fn adapter for Trainer/launcher/benchmark use."""
        def fn(step: int) -> Dict[str, np.ndarray]:
            return {k: v for k, v in self.sample_batch(step, batch,
                                                       **kw).items()
                    if k != "lang"}
        return fn

    def translate(self, src_content: np.ndarray, lang: int) -> np.ndarray:
        return self.perms[lang][src_content][::-1]

    def _draws(self, step: int, batch: int, *, shard: int, n_shards: int,
               lang: Optional[int]) -> Dict[str, np.ndarray]:
        """All of the batch's randomness, drawn up-front in a fixed order.

        Both assembly paths (vectorized / loop reference) consume exactly
        this dict, so they are equal by construction; the draw ORDER here
        is the data-stream contract behind --resume."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + shard)
        b = batch // n_shards
        n_max = cfg.src_len[1]
        langs = (np.full((b,), lang, np.int64) if lang is not None
                 else rng.choice(cfg.n_langs, size=b, p=self.lang_weights
                                 ).astype(np.int64))
        n = rng.integers(cfg.src_len[0], cfg.src_len[1] + 1, size=b)
        content = rng.choice(self.n_content, size=(b, n_max), p=self.content_p)
        d = {"langs": langs, "n": n, "content": content}
        if cfg.dae_frac > 0:
            d["dae_u"] = rng.random(b)
            d["keep_u"] = rng.random((b, n_max))
        return d

    def sample_batch(self, step: int, batch: int, *, shard: int = 0,
                     n_shards: int = 1, lang: Optional[int] = None,
                     ) -> Dict[str, np.ndarray]:
        """One global batch, pure numpy array ops; shards draw disjoint
        sub-batches. Equal to ``sample_batch_loop`` element-for-element."""
        cfg = self.cfg
        d = self._draws(step, batch, shard=shard, n_shards=n_shards, lang=lang)
        langs, n, content = d["langs"], d["n"], d["content"]
        b, n_max = content.shape
        L = cfg.max_len
        fc = self.first_content
        pos = np.arange(n_max)[None, :]
        valid = pos < n[:, None]                       # (b, n_max)

        if cfg.dae_frac > 0:
            is_dae = d["dae_u"] < cfg.dae_frac
            keep = valid & (d["keep_u"] > 0.15)
            # DAE rows where everything was corrupted keep the first token
            keep[is_dae & ~keep.any(1), 0] = True
        else:
            is_dae = np.zeros(b, bool)
            keep = valid

        # source: DAE rows compact the surviving tokens (stable order), MT
        # rows take the first n as-is
        src_mask = np.where(is_dae[:, None], keep, valid)
        order = np.argsort(~src_mask, axis=1, kind="stable")
        src = np.take_along_axis(content, order, axis=1)
        src_len = src_mask.sum(1)

        # target: DAE reconstructs the clean source; MT applies the
        # per-language permutation then reverses the first n tokens
        perm = np.stack(self.perms)                    # (n_langs, n_content)
        t_fwd = perm[langs[:, None], content]
        rev = np.take_along_axis(t_fwd, np.maximum(n[:, None] - 1 - pos, 0),
                                 axis=1)
        tgt = np.where(is_dae[:, None], content, rev)

        rows = np.arange(b)
        W = max(L, n_max + 2)
        enc = np.full((b, W), PAD, np.int64)
        enc[:, 0] = 3 + langs
        enc[:, 1:1 + n_max] = np.where(pos < src_len[:, None], src + fc, PAD)
        enc[rows, 1 + src_len] = EOS
        enc = np.ascontiguousarray(enc[:, :L])

        m = np.minimum(n, L - 1)
        body = np.where(pos < m[:, None], tgt + fc, PAD)[:, :L - 1]
        dec = np.full((b, L), PAD, np.int64)
        dec[:, 0] = BOS
        dec[:, 1:1 + body.shape[1]] = body
        lab = np.full((b, L), PAD, np.int64)
        lab[:, :body.shape[1]] = body
        lab[rows, m] = EOS
        msk = (np.arange(L)[None, :] < (m + 1)[:, None]).astype(np.float32)
        return {"enc_tokens": enc, "tokens": dec, "labels": lab,
                "loss_mask": msk, "lang": langs}

    def sample_batch_loop(self, step: int, batch: int, *, shard: int = 0,
                          n_shards: int = 1, lang: Optional[int] = None,
                          ) -> Dict[str, np.ndarray]:
        """Per-sample loop assembly over the same draws — the readable
        reference the vectorized path is tested against."""
        cfg = self.cfg
        d = self._draws(step, batch, shard=shard, n_shards=n_shards, lang=lang)
        b = d["content"].shape[0]
        L = cfg.max_len
        enc = np.full((b, L), PAD, np.int64)
        dec = np.full((b, L), PAD, np.int64)
        lab = np.full((b, L), PAD, np.int64)
        msk = np.zeros((b, L), np.float32)
        for i in range(b):
            l = int(d["langs"][i])
            n = int(d["n"][i])
            s = d["content"][i, :n]
            is_dae = cfg.dae_frac > 0 and d["dae_u"][i] < cfg.dae_frac
            if is_dae:
                # denoising auto-encoding: corrupt source, reconstruct it
                keep = d["keep_u"][i, :n] > 0.15
                src_tokens = s[keep] if keep.any() else s[:1]
                tgt = s
            else:
                src_tokens = s
                tgt = self.translate(s, l)
            enc_row = np.concatenate([[self.lang_tag(l)],
                                      src_tokens + self.first_content, [EOS]])
            tgt_row = tgt + self.first_content
            enc[i, :len(enc_row)] = enc_row[:L]
            dec[i, 0] = BOS
            m = min(len(tgt_row), L - 1)
            dec[i, 1:1 + m] = tgt_row[:m]
            lab[i, :m] = tgt_row[:m]
            lab[i, m] = EOS
            msk[i, :m + 1] = 1.0
        return {"enc_tokens": enc, "tokens": dec, "labels": lab,
                "loss_mask": msk, "lang": d["langs"]}


@dataclass(frozen=True)
class LMTaskConfig:
    vocab: int = 512
    seq_len: int = 128
    order: int = 2                   # Markov order of the synthetic source
    seed: int = 99


class SyntheticLM:
    """Deterministic Markov-chain LM data (decoder-only archs)."""

    def __init__(self, cfg: LMTaskConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse-ish transition: each context maps to ~8 likely next tokens
        self.a = int(rng.integers(3, 97)) * 2 + 1
        self.b = int(rng.integers(1, cfg.vocab))
        self.noise_p = 0.1

    def _draws(self, step: int, batch: int, *, shard: int, n_shards: int
               ) -> Dict[str, np.ndarray]:
        """Up-front draws in a fixed order (the --resume stream contract):
        initial tokens, then per-step noise uniforms, then noise values."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 999_983 + step) * 4096 + shard)
        b = batch // n_shards
        L = cfg.seq_len
        return {"init": rng.integers(3, cfg.vocab, size=b),
                "noise_u": rng.random((L, b)),
                "noise_v": rng.integers(3, cfg.vocab, size=(L, b))}

    def sample_batch(self, step: int, batch: int, *, shard: int = 0,
                     n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Vectorized over BOTH batch and time: the affine chain map
        g(x) = (a*x + b) mod m + 3 composes in closed form
        (g^k(x) = (A_k*x + B_k) mod m + 3), so every position is computed
        directly from its most recent noise reset — no sequential loop.
        Equal to ``sample_batch_loop`` element-for-element."""
        cfg = self.cfg
        d = self._draws(step, batch, shard=shard, n_shards=n_shards)
        b = d["init"].shape[0]
        L = cfg.seq_len
        m = cfg.vocab - 3
        # iterated-map coefficients: A_{k+1} = a*A_k, B_{k+1} = a*(B_k+3) + b
        # (mod m), with A_0 = 1, B_0 = -3 so that g^0 is the identity on
        # the +3-shifted domain
        A = np.zeros(L + 1, np.int64)
        B = np.zeros(L + 1, np.int64)
        A[0], B[0] = 1, -3 % m
        for k in range(L):
            A[k + 1] = (self.a * A[k]) % m
            B[k + 1] = (self.a * (B[k] + 3) + self.b) % m
        cols = np.arange(L + 1)[None, :]
        noise = d["noise_u"] < self.noise_p              # (L, b)
        # column j>0 is a reset iff noise fired at step j-1; column 0 always
        reset = np.concatenate([np.ones((b, 1), bool), noise.T], axis=1)
        last = np.maximum.accumulate(np.where(reset, cols, 0), axis=1)
        seed_vals = np.concatenate([d["init"][:, None], d["noise_v"].T], axis=1)
        base = np.take_along_axis(np.where(reset, seed_vals, 0), last, axis=1)
        k = cols - last
        toks = (A[k] * base + B[k]) % m + 3
        return {"tokens": toks[:, :L], "labels": toks[:, 1:],
                "loss_mask": np.ones((b, L), np.float32)}

    def sample_batch_loop(self, step: int, batch: int, *, shard: int = 0,
                          n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Sequential-chain assembly over the same draws — the readable
        reference the closed-form path is tested against."""
        cfg = self.cfg
        d = self._draws(step, batch, shard=shard, n_shards=n_shards)
        b = d["init"].shape[0]
        L = cfg.seq_len
        toks = np.zeros((b, L + 1), np.int64)
        toks[:, 0] = d["init"]
        for t in range(L):
            nxt = (self.a * toks[:, t] + self.b) % (cfg.vocab - 3) + 3
            noise = d["noise_u"][t] < self.noise_p
            toks[:, t + 1] = np.where(noise, d["noise_v"][t], nxt)
        return {"tokens": toks[:, :L], "labels": toks[:, 1:],
                "loss_mask": np.ones((b, L), np.float32)}
