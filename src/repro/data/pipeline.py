"""Deterministic synthetic data pipelines.

The paper's task (multilingual MT on WMT-10 / Web-50) is not
redistributable, so we generate a *structured* synthetic analogue that
preserves the property Gating Dropout exploits: per-language structure
that experts can specialize on.

Multilingual MT task: each "language" l has a seeded token permutation
pi_l. A sample for direction (l_src -> l_tgt) is
    source  = [tag(l_tgt)] s_1..s_n [EOS]
    target  = reverse(pi_{l_tgt}(s))               (so the model must learn
                                                    a per-language mapping +
                                                    a global reordering rule)
Low-resource languages appear with small sampling weight — the Table-4
(low) split. Everything is a pure function of (seed, step, shard), so the
pipeline is reproducible and shards are disjoint by construction.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2


@dataclass(frozen=True)
class MTTaskConfig:
    vocab: int = 512
    n_langs: int = 8
    low_resource_frac: float = 0.25   # last quarter of langs are low-resource
    low_resource_weight: float = 0.05
    src_len: Tuple[int, int] = (8, 24)
    max_len: int = 32
    seed: int = 1234
    dae_frac: float = 0.0             # fraction of DAE (denoising) samples


class MultilingualMT:
    """Deterministic multilingual translation generator."""

    def __init__(self, cfg: MTTaskConfig):
        self.cfg = cfg
        self.first_content = 3 + cfg.n_langs
        self.n_content = cfg.vocab - self.first_content
        assert self.n_content > 10, "vocab too small"
        root = np.random.default_rng(cfg.seed)
        self.perms = [root.permutation(self.n_content)
                      for _ in range(cfg.n_langs)]
        n_low = max(1, int(cfg.n_langs * cfg.low_resource_frac))
        w = np.ones(cfg.n_langs)
        w[-n_low:] = cfg.low_resource_weight
        self.lang_weights = w / w.sum()
        self.low_langs = list(range(cfg.n_langs - n_low, cfg.n_langs))
        # Zipf-ish content distribution
        ranks = np.arange(1, self.n_content + 1)
        zipf = 1.0 / ranks ** 1.1
        self.content_p = zipf / zipf.sum()

    def lang_tag(self, lang: int) -> int:
        return 3 + lang

    def translate(self, src_content: np.ndarray, lang: int) -> np.ndarray:
        return self.perms[lang][src_content][::-1]

    def sample_batch(self, step: int, batch: int, *, shard: int = 0,
                     n_shards: int = 1, lang: Optional[int] = None,
                     ) -> Dict[str, np.ndarray]:
        """One global batch; shards draw disjoint sub-batches."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + shard)
        b = batch // n_shards
        L = cfg.max_len
        enc = np.full((b, L), PAD, np.int64)
        dec = np.full((b, L), PAD, np.int64)
        lab = np.full((b, L), PAD, np.int64)
        msk = np.zeros((b, L), np.float32)
        langs = np.zeros((b,), np.int64)
        for i in range(b):
            l = lang if lang is not None else rng.choice(
                cfg.n_langs, p=self.lang_weights)
            n = rng.integers(cfg.src_len[0], cfg.src_len[1] + 1)
            s = rng.choice(self.n_content, size=n, p=self.content_p)
            is_dae = rng.random() < cfg.dae_frac
            if is_dae:
                # denoising auto-encoding: corrupt source, reconstruct it
                keep = rng.random(n) > 0.15
                src_tokens = s[keep] if keep.any() else s[:1]
                tgt = s
            else:
                src_tokens = s
                tgt = self.translate(s, int(l))
            enc_row = np.concatenate([[self.lang_tag(int(l))],
                                      src_tokens + self.first_content, [EOS]])
            tgt_row = tgt + self.first_content
            enc[i, :len(enc_row)] = enc_row[:L]
            dec[i, 0] = BOS
            m = min(len(tgt_row), L - 1)
            dec[i, 1:1 + m] = tgt_row[:m]
            lab[i, :m] = tgt_row[:m]
            lab[i, m] = EOS
            msk[i, :m + 1] = 1.0
            langs[i] = l
        return {"enc_tokens": enc, "tokens": dec, "labels": lab,
                "loss_mask": msk, "lang": langs}


@dataclass(frozen=True)
class LMTaskConfig:
    vocab: int = 512
    seq_len: int = 128
    order: int = 2                   # Markov order of the synthetic source
    seed: int = 99


class SyntheticLM:
    """Deterministic Markov-chain LM data (decoder-only archs)."""

    def __init__(self, cfg: LMTaskConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse-ish transition: each context maps to ~8 likely next tokens
        self.a = int(rng.integers(3, 97)) * 2 + 1
        self.b = int(rng.integers(1, cfg.vocab))
        self.noise_p = 0.1

    def sample_batch(self, step: int, batch: int, *, shard: int = 0,
                     n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 999_983 + step) * 4096 + shard)
        b = batch // n_shards
        L = cfg.seq_len
        toks = np.zeros((b, L + 1), np.int64)
        toks[:, 0] = rng.integers(3, cfg.vocab, size=b)
        for t in range(L):
            nxt = (self.a * toks[:, t] + self.b) % (cfg.vocab - 3) + 3
            noise = rng.random(b) < self.noise_p
            nxt = np.where(noise, rng.integers(3, cfg.vocab, size=b), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :L], "labels": toks[:, 1:],
                "loss_mask": np.ones((b, L), np.float32)}
