"""Double-buffered background-thread data pipeline (DESIGN.md §8).

The Trainer consumes training data as CHUNKS: the per-step batches of K
consecutive steps stacked on a new leading axis, fed to one scan-fused
executable. Chunk synthesis is pure host work (vectorized numpy,
``repro.data.pipeline``), so it can overlap device compute entirely: the
``Prefetcher`` maps a producer function over a work list on a daemon
thread into a depth-bounded queue (depth 2 = double buffering — chunk
c+1 is synthesized while the device runs chunk c). Host residency is
bounded at depth + 2 chunks: the queue, plus one finished chunk the
worker may hold while the queue is full, plus the one the consumer
holds.

The producer runs numpy only; device transfer happens on the consumer
side at dispatch, so no jax calls ever run on the worker thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.obs.trace import Tracer, get_tracer

Batch = Dict[str, np.ndarray]


def stack_batches(batch_fn: Callable[[int], Batch], start: int, stop: int
                  ) -> Batch:
    """``batch_fn(i)`` for i in [start, stop), stacked on a new leading
    axis — the input format of one scan-fused train chunk."""
    bs = [batch_fn(i) for i in range(start, stop)]
    return {k: np.stack([np.asarray(b[k]) for b in bs]) for k in bs[0]}


class Prefetcher:
    """Background-thread ``map(fn, items)`` with a bounded buffer.

    Iterating yields ``fn(item)`` in submission order. An exception in
    ``fn`` is re-raised at the consuming ``__next__``. ``close()`` stops
    the worker early (abnormal consumer exit must never leave the thread
    blocked on a full queue, hence the put-with-timeout loop).
    """

    def __init__(self, fn: Callable[[Any], Any], items: Iterable[Any],
                 depth: int = 2, tracer: Optional[Tracer] = None):
        self._q: "queue.Queue[Tuple[str, Any]]" = queue.Queue(
            maxsize=max(int(depth), 1))
        self._stop = threading.Event()
        self._fn = fn
        self._items = items
        self._done = False
        # produce spans record on the worker thread (their own Perfetto
        # track), wait spans on the consumer: a wait span with nonzero
        # duration is exactly the time the device loop stalled on data
        self._tracer = tracer if tracer is not None else get_tracer()
        self._thread = threading.Thread(
            target=self._work, name="prefetcher", daemon=True)
        self._thread.start()

    def _put(self, msg: Tuple[str, Any]) -> None:
        while not self._stop.is_set():
            try:
                self._q.put(msg, timeout=0.1)
                return
            except queue.Full:
                continue

    def _work(self) -> None:
        try:
            for item in self._items:
                if self._stop.is_set():
                    return
                with self._tracer.span("prefetch.produce", item=str(item)):
                    out = self._fn(item)
                self._put(("ok", out))
            self._put(("end", None))
        except BaseException as e:  # noqa: BLE001 — surfaced to consumer
            self._put(("err", e))

    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self) -> Any:
        if self._done:
            raise StopIteration
        with self._tracer.span("prefetch.wait"):
            kind, val = self._q.get()
        if kind == "ok":
            return val
        self._done = True
        if kind == "err":
            raise val
        raise StopIteration

    def close(self) -> None:
        """Stop the worker and release its queue slot; idempotent."""
        self._stop.set()
        self._done = True
        try:  # unblock a worker waiting on a full queue
            self._q.get_nowait()
        except queue.Empty:
            pass

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
