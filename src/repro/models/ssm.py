"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward (block decomposition: intra-chunk quadratic part +
inter-chunk linear state recurrence) and O(1)-state decode recurrence.
The naive full recurrence lives in tests as the oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def init_ssm(key: jax.Array, cfg: ModelConfig, dtype,
             out_scale: float = 1.0) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    h = din // s.head_dim
    gn = s.n_groups * s.d_state
    conv_ch = din + 2 * gn
    ks = jax.random.split(key, 8)
    sd = d ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d, din), dtype) * sd,
        "w_x": jax.random.normal(ks[1], (d, din), dtype) * sd,
        "w_B": jax.random.normal(ks[2], (d, gn), dtype) * sd,
        "w_C": jax.random.normal(ks[3], (d, gn), dtype) * sd,
        "w_dt": jax.random.normal(ks[4], (d, h), dtype) * sd,
        "dt_bias": jnp.zeros((h,), dtype) + jnp.log(jnp.expm1(jnp.asarray(0.01, dtype))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "conv_w": jax.random.normal(ks[5], (s.conv_kernel, conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "out_norm": jnp.ones((din,), dtype),
        "w_out": jax.random.normal(ks[6], (din, d), dtype) * (din ** -0.5) * out_scale,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    return (yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + eps)
            * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, bs: jax.Array,
                cs: jax.Array, chunk: int,
                h0: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. xh: (B,L,H,P); dt: (B,L,H); a: (H,) negative;
    bs, cs: (B,L,G,N). Returns y (B,L,H,P) and final state (B,H,P,N)."""
    b, l, h, p = xh.shape
    g, n = bs.shape[2], bs.shape[3]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xc = xh.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = jnp.repeat(bs.reshape(b, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(cs.reshape(b, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)
    da = dtc * a.astype(jnp.float32)                      # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                          # within-chunk cumsum
    # ---- intra-chunk (quadratic within chunk) ----
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc)     # (B,nc,Q,Q,H)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    att = jnp.where(causal, scores * decay, 0.0) * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)
    # ---- chunk states ----
    last = cum[:, :, -1:, :]                              # (B,nc,1,H)
    w_state = jnp.exp(last - cum) * dtc                   # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", bc, w_state, xc)
    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(last[:, :, 0, :])               # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(hprev, xs):
        dec, s_c = xs                                     # (B,H), (B,H,P,N)
        hnew = hprev * dec[..., None, None] + s_c
        return hnew, hprev

    hfin, h_in = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N) state entering chunk
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", cc, h_in, jnp.exp(cum))
    y = (y_diag + y_inter).reshape(b, l, h, p)
    return y.astype(xh.dtype), hfin


def ssm_apply(prm: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence Mamba-2 mixer (train / prefill)."""
    s = cfg.ssm
    b, l, d = x.shape
    din = s.d_inner(d)
    h = din // s.head_dim
    gn = s.n_groups * s.d_state
    xc = x.astype(prm["w_z"].dtype)
    z = xc @ prm["w_z"]
    xbc = jnp.concatenate([xc @ prm["w_x"], xc @ prm["w_B"], xc @ prm["w_C"]], -1)
    xbc = jax.nn.silu(_causal_conv(xbc, prm["conv_w"], prm["conv_b"]))
    xs = xbc[..., :din].reshape(b, l, h, s.head_dim)
    bs = xbc[..., din:din + gn].reshape(b, l, s.n_groups, s.d_state)
    cs = xbc[..., din + gn:].reshape(b, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus((xc @ prm["w_dt"]).astype(jnp.float32)
                         + prm["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(prm["A_log"].astype(jnp.float32))
    pad = (-l) % s.chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bs = jnp.pad(bs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cs = jnp.pad(cs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, _ = ssd_chunked(xs, dt, a, bs, cs, s.chunk)
    y = y[:, :l]
    y = y + prm["D"].astype(y.dtype)[None, None, :, None] * xs[:, :l].astype(y.dtype)
    y = _gated_norm(y.reshape(b, l, din), z, prm["out_norm"])
    return (y @ prm["w_out"]).astype(x.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    h = din // s.head_dim
    gn = s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, din + 2 * gn), dtype),
        "h": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(prm: Params, x: jax.Array, cache: Params,
               cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One-token recurrent step. x: (B, 1, d)."""
    s = cfg.ssm
    b, _, d = x.shape
    din = s.d_inner(d)
    h = din // s.head_dim
    gn = s.n_groups * s.d_state
    xc = x[:, 0].astype(prm["w_z"].dtype)
    z = xc @ prm["w_z"]
    xbc_new = jnp.concatenate([xc @ prm["w_x"], xc @ prm["w_B"], xc @ prm["w_C"]], -1)
    win = jnp.concatenate([cache["conv"],
                           xbc_new[:, None].astype(cache["conv"].dtype)], 1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", win, prm["conv_w"]) + prm["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xs = xbc[:, :din].reshape(b, h, s.head_dim)
    bs = jnp.repeat(xbc[:, din:din + gn].reshape(b, s.n_groups, s.d_state),
                    h // s.n_groups, axis=1)
    cs = jnp.repeat(xbc[:, din + gn:].reshape(b, s.n_groups, s.d_state),
                    h // s.n_groups, axis=1)
    dt = jax.nn.softplus((xc @ prm["w_dt"]).astype(jnp.float32)
                         + prm["dt_bias"].astype(jnp.float32))    # (B,H)
    a = -jnp.exp(prm["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                         # (B,H)
    hn = (cache["h"] * dec[..., None, None]
          + jnp.einsum("bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32),
                       bs.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", cs.astype(jnp.float32), hn)
    y = y + prm["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = _gated_norm(y.reshape(b, din).astype(x.dtype), z, prm["out_norm"])
    out = (y @ prm["w_out"]).astype(x.dtype)[:, None]
    return out, {"conv": win[:, 1:], "h": hn.astype(cache["h"].dtype)}
