"""Unified transformer assembly for every assigned architecture.

Layers are organised into SEGMENTS — contiguous repeats of a (possibly
multi-layer) pattern of LayerSpecs — and executed with ``jax.lax.scan``
over the stacked per-repeat parameters (MaxText-style). This keeps the
HLO size O(#segments), not O(#layers): essential for the 100-layer VLM
and 61-layer DeepSeek dry-runs on a 512-device mesh.

Modes:
  train   -- full sequence, logits for every position, MoE aux losses.
  prefill -- full sequence + returns a decode cache.
  decode  -- one token against the cache.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import ParallelContext, init_moe_params, moe_apply
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as M
from repro.models import ssm as S

Params = Dict[str, Any]


def constrain(x: jax.Array, ctx, spec_dims) -> jax.Array:
    """Best-effort sharding constraint (no-op without an active mesh).
    spec_dims: tuple where 'dp'/'tp' resolve to mesh axes; None kept."""
    if ctx is None or not getattr(ctx, "active", False):
        return x
    import numpy as _np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    dims = []
    for dim, s in zip(x.shape, spec_dims):
        if s == "dp":
            size = int(_np.prod([mesh.shape[a] for a in ctx.dp_axes]))
            dims.append(ctx.dp_axes if dim % size == 0 else None)
        elif s == "tp":
            tp = ctx.tp_axis if ctx.tp_axis in mesh.axis_names else None
            dims.append(tp if tp and dim % mesh.shape[tp] == 0 else None)
        else:
            dims.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "gqa"        # gqa | mla | ssm | hybrid | none (cross-only)
    cross: bool = False       # cross-attention sub-layer
    gated_cross: bool = False # VLM: tanh-gated cross-attn layer (no self-attn)
    moe: bool = False
    window: int = 0           # sliding window (0 = full)
    causal: bool = True


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int


def _compress(specs: List[LayerSpec]) -> List[Segment]:
    """Compress a per-layer spec list into segments: whole-list periodic
    pattern if one exists (period <= 8), else maximal identical runs."""
    n = len(specs)
    for p in range(1, 9):
        if n % p == 0 and n // p > 1:
            if all(specs[i] == specs[i % p] for i in range(n)):
                return [Segment(tuple(specs[:p]), n // p)]
    segs: List[Segment] = []
    i = 0
    while i < n:
        j = i
        while j < n and specs[j] == specs[i]:
            j += 1
        segs.append(Segment((specs[i],), j - i))
        i = j
    return segs


def layer_plan(cfg: ModelConfig, *, encoder: bool = False) -> List[Segment]:
    specs: List[LayerSpec] = []
    if encoder:
        assert cfg.encdec is not None
        for i in range(cfg.encdec.n_encoder_layers):
            specs.append(LayerSpec(
                mixer="gqa", causal=cfg.encdec.encoder_causal,
                moe=cfg.moe is not None and cfg.moe.is_moe_layer(i)))
        return _compress(specs)

    for i in range(cfg.n_layers):
        moe = cfg.moe is not None and cfg.moe.is_moe_layer(i)
        if cfg.family == "ssm":
            specs.append(LayerSpec(mixer="ssm", moe=moe))
        elif cfg.family == "hybrid":
            is_global = i in cfg.hybrid.global_attn_layers
            specs.append(LayerSpec(
                mixer="hybrid", moe=moe,
                window=0 if is_global else cfg.sliding_window))
        elif cfg.family == "encdec":
            specs.append(LayerSpec(mixer="gqa", cross=True, moe=moe))
        else:
            specs.append(LayerSpec(
                mixer="mla" if cfg.mla is not None else "gqa",
                moe=moe, window=cfg.sliding_window))
    if cfg.family == "vlm":
        v = cfg.vlm
        out: List[LayerSpec] = []
        for i, s in enumerate(specs):
            if i % v.cross_attn_period == 0:
                out.append(LayerSpec(mixer="none", gated_cross=True, cross=True))
            else:
                out.append(s)
        specs = out
    return _compress(specs)


def plan_layer_indices(segs: List[Segment]):
    """Yield (seg_idx, repeat, pos, global_layer_idx)."""
    g = 0
    for si, seg in enumerate(segs):
        for r in range(seg.repeats):
            for pi in range(len(seg.pattern)):
                yield si, r, pi, g
                g += 1


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, spec: LayerSpec, cfg: ModelConfig,
                dtype, n_total: int) -> Params:
    ks = jax.random.split(key, 8)
    out_scale = (2 * max(n_total, 1)) ** -0.5
    p: Params = {}
    if spec.mixer != "none":
        p["ln1"] = L.init_norm(cfg, cfg.d_model, dtype)
    if spec.mixer == "gqa":
        p["attn"] = A.init_attn(ks[0], cfg, dtype, out_scale)
    elif spec.mixer == "mla":
        p["attn"] = M.init_mla(ks[0], cfg, dtype, out_scale)
    elif spec.mixer == "ssm":
        p["ssm"] = S.init_ssm(ks[1], cfg, dtype, out_scale)
    elif spec.mixer == "hybrid":
        p["attn"] = A.init_attn(ks[0], cfg, dtype, out_scale)
        p["ssm"] = S.init_ssm(ks[1], cfg, dtype, out_scale)
        p["mix_norm_attn"] = jnp.ones((cfg.d_model,), dtype)
        p["mix_norm_ssm"] = jnp.ones((cfg.d_model,), dtype)
    if spec.cross:
        kv_dim = None
        p["ln_cross"] = L.init_norm(cfg, cfg.d_model, dtype)
        p["cross"] = A.init_cross_attn(ks[2], cfg, dtype, kv_dim, out_scale)
        if spec.gated_cross:
            p["gate_attn"] = jnp.zeros((), dtype)
            p["gate_ffn"] = jnp.zeros((), dtype)
    p["ln2"] = L.init_norm(cfg, cfg.d_model, dtype)
    if spec.moe:
        p["moe"] = init_moe_params(ks[3], cfg, dtype=dtype)
        if cfg.moe.n_shared_experts > 0:
            dffs = cfg.moe.d_ff(cfg.d_ff) * cfg.moe.n_shared_experts
            p["shared"] = L.init_ffn(ks[4], cfg.d_model, dffs, cfg, dtype,
                                     out_scale)
    elif cfg.d_ff > 0 or spec.gated_cross:
        dff = cfg.d_ff if cfg.d_ff > 0 else 4 * cfg.d_model
        p["ffn"] = L.init_ffn(ks[4], cfg.d_model, dff, cfg, dtype, out_scale)
    return p


# ---------------------------------------------------------------------------
# per-layer cache init
# ---------------------------------------------------------------------------

def _init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                      max_seq: int, n_cross: int, dtype) -> Params:
    c: Params = {}
    if spec.mixer in ("gqa", "hybrid"):
        if spec.window > 0:
            c["attn"] = A.init_ring_cache(cfg, batch, spec.window, dtype)
        else:
            c["attn"] = A.init_kv_cache(cfg, batch, max_seq, dtype)
    elif spec.mixer == "mla":
        c["attn"] = M.init_mla_cache(cfg, batch, max_seq, dtype)
    if spec.mixer in ("ssm", "hybrid"):
        c["ssm"] = S.init_ssm_cache(cfg, batch, dtype)
    if spec.cross:
        h, hd = cfg.n_heads, cfg.head_dim_
        c["cross"] = {"k": jnp.zeros((batch, n_cross, h, hd), dtype),
                      "v": jnp.zeros((batch, n_cross, h, hd), dtype)}
    return c


# ---------------------------------------------------------------------------
# per-layer apply
# ---------------------------------------------------------------------------

def _moe_or_ffn(p: Params, spec: LayerSpec, h: jax.Array, cfg: ModelConfig,
                ctx, rng, decision, is_training, token_ids,
                token_valid=None):
    if spec.moe:
        y, aux = moe_apply(p["moe"], h, cfg, ctx, rng=rng, decision=decision,
                           is_training=is_training, token_ids=token_ids,
                           token_valid=token_valid)
        if "shared" in p:
            y = y + L.ffn_apply(p["shared"], h, cfg)
        return y, aux
    # shared zero-aux (core/moe.py) so every branch of every cond keeps
    # the same aux pytree keys — a locally-maintained copy would desync
    from repro.core.moe import _zero_aux
    zero = _zero_aux(cfg.moe.n_experts if cfg.moe is not None else 1)
    if "ffn" in p:
        return L.ffn_apply(p["ffn"], h, cfg), zero
    return jnp.zeros_like(h), zero


def _layer_apply(spec: LayerSpec, p: Params, x: jax.Array, cfg: ModelConfig,
                 ctx, *, mode: str, cache: Optional[Params],
                 index, rng, decision, is_training: bool,
                 cross_src: Optional[jax.Array], token_ids,
                 token_valid=None,
                 flash_decode: bool = False,
                 block_tables=None) -> Tuple[jax.Array,
                                             Optional[Params], Dict]:
    """One transformer layer. Returns (x, new_cache, aux)."""
    new_cache: Params = {}
    b, l, d = x.shape
    # ---- mixer (self-attention / ssm / hybrid) ----
    if spec.mixer != "none":
        h = L.norm_apply(p["ln1"], x, cfg)
        outs = []
        if spec.mixer in ("gqa", "hybrid"):
            if mode == "decode":
                # windowed layers keep their slot-addressed ring cache;
                # only full-cache layers read through the page table
                o, nc = A.decode_self_attention(
                    p["attn"], h, cache["attn"], cfg, index,
                    window=spec.window, flash=flash_decode,
                    block_tables=None if spec.window > 0 else block_tables)
                new_cache["attn"] = nc
            else:
                q, k, v = A.attn_qkv(p["attn"], h)
                pos = jnp.arange(l)
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
                if (cfg.banded_swa and spec.window > 0 and spec.causal
                        and l > 2 * spec.window):
                    from repro.models.flash import banded_flash_attention
                    qc = 1024 if l % 1024 == 0 or l > 4096 else 512
                    o = banded_flash_attention(q, k, v, spec.window,
                                               q_chunk=qc, kv_chunk=512,
                                               use_full=not cfg.scan_layers)
                else:
                    o = A.flash_attention(q, k, v, causal=spec.causal,
                                          window=spec.window)
                o = A.attn_out(p["attn"], o, x.dtype)
                if mode == "prefill":
                    new_cache["attn"] = _fill_kv_cache(
                        spec, cfg, cache["attn"], k, v)
            outs.append(o)
        if spec.mixer == "mla":
            if mode == "decode":
                o, nc = M.mla_decode(p["attn"], h, cache["attn"], cfg, index,
                                     block_tables=block_tables)
                new_cache["attn"] = nc
            else:
                o, (c_kv, k_rope) = M.mla_attention(p["attn"], h, cfg,
                                                    return_cache=True)
                if mode == "prefill":
                    smax = cache["attn"]["c_kv"].shape[1]
                    cdt = cache["attn"]["c_kv"].dtype
                    new_cache["attn"] = {
                        "c_kv": _pad_to(c_kv.astype(cdt), smax, 1),
                        "k_rope": _pad_to(k_rope.astype(cdt), smax, 1),
                    }
            outs.append(o)
        if spec.mixer in ("ssm", "hybrid"):
            if mode == "decode":
                o, nc = S.ssm_decode(p["ssm"], h, cache["ssm"], cfg)
                new_cache["ssm"] = nc
            else:
                o = S.ssm_apply(p["ssm"], h, cfg)
                if mode == "prefill":
                    new_cache["ssm"] = _fill_ssm_cache(p["ssm"], h, cfg)
            outs.append(o)
        if spec.mixer == "hybrid":
            oa = _rms_scale(outs[0], p["mix_norm_attn"])
            os_ = _rms_scale(outs[1], p["mix_norm_ssm"])
            mixed = 0.5 * (oa + os_)
        else:
            mixed = outs[0]
        x = x + mixed
    # ---- cross attention ----
    if spec.cross:
        h = L.norm_apply(p["ln_cross"] if "ln_cross" in p else p["ln1"], x, cfg)
        if mode == "decode" or cross_src is None:
            ck, cv = cache["cross"]["k"], cache["cross"]["v"]
        else:
            ck, cv = A.make_cross_kv(p["cross"], cross_src)
            if mode == "prefill":
                cdt = cache["cross"]["k"].dtype
                new_cache["cross"] = {"k": ck.astype(cdt), "v": cv.astype(cdt)}
        o = A.cross_attention_kv(p["cross"], h, ck, cv)
        if spec.gated_cross:
            o = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(o.dtype) * o
        x = x + o
        if mode in ("prefill", "decode") and "cross" not in new_cache:
            new_cache["cross"] = cache["cross"]   # carried through unchanged
    # ---- FFN / MoE ----
    h = L.norm_apply(p["ln2"], x, cfg)
    y, aux = _moe_or_ffn(p, spec, h, cfg, ctx, rng, decision, is_training,
                         token_ids, token_valid)
    if spec.gated_cross:
        y = jnp.tanh(p["gate_ffn"].astype(jnp.float32)).astype(y.dtype) * y
    x = x + y
    return x, (new_cache if mode in ("prefill", "decode") else None), aux


def _rms_scale(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _pad_to(x, n, axis):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad)


def _fill_kv_cache(spec: LayerSpec, cfg: ModelConfig, cache, k, v):
    b, l = k.shape[0], k.shape[1]
    if spec.window > 0 and cache["k"].shape[1] == spec.window:
        w = spec.window
        if l >= w:
            kk, vv = k[:, l - w:], v[:, l - w:]
            pos = jnp.arange(l - w, l, dtype=jnp.int32)
        else:
            kk, vv = _pad_to(k, w, 1), _pad_to(v, w, 1)
            pos = jnp.where(jnp.arange(w) < l, jnp.arange(w), -1).astype(jnp.int32)
        # ring layout: slot = pos % w
        slots = jnp.where(pos >= 0, pos % w, jnp.arange(w))
        ck = jnp.zeros_like(cache["k"]).at[:, slots].set(kk.astype(cache["k"].dtype))
        cv = jnp.zeros_like(cache["v"]).at[:, slots].set(vv.astype(cache["v"].dtype))
        cpos = jnp.full((w,), -1, jnp.int32).at[slots].set(pos)
        return {"k": ck, "v": cv, "pos": cpos}
    smax = cache["k"].shape[1]
    return {"k": _pad_to(k.astype(cache["k"].dtype), smax, 1),
            "v": _pad_to(v.astype(cache["v"].dtype), smax, 1)}


def _fill_ssm_cache(prm, h, cfg: ModelConfig):
    """Recompute the SSM final state for the prefix (prefill)."""
    s = cfg.ssm
    b, l, d = h.shape
    din = s.d_inner(d)
    nh = din // s.head_dim
    gn = s.n_groups * s.d_state
    xc = h.astype(prm["w_z"].dtype)
    xbc = jnp.concatenate([xc @ prm["w_x"], xc @ prm["w_B"], xc @ prm["w_C"]], -1)
    conv_tail = xbc[:, -(s.conv_kernel - 1):]
    if l < s.conv_kernel - 1:
        conv_tail = jnp.pad(xbc, ((0, 0), (s.conv_kernel - 1 - l, 0), (0, 0)))
    xbc_c = jax.nn.silu(S._causal_conv(xbc, prm["conv_w"], prm["conv_b"]))
    xs = xbc_c[..., :din].reshape(b, l, nh, s.head_dim)
    bs = xbc_c[..., din:din + gn].reshape(b, l, s.n_groups, s.d_state)
    cs = xbc_c[..., din + gn:].reshape(b, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus((xc @ prm["w_dt"]).astype(jnp.float32)
                         + prm["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(prm["A_log"].astype(jnp.float32))
    pad = (-l) % s.chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bs = jnp.pad(bs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cs = jnp.pad(cs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    _, hfin = S.ssd_chunked(xs, dt, a, bs, cs, s.chunk)
    return {"conv": conv_tail, "h": hfin}


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------

def init_stack(key: jax.Array, segs: List[Segment], cfg: ModelConfig,
               dtype, n_total: int) -> List[Params]:
    params: List[Params] = []
    for si, seg in enumerate(segs):
        seg_p: Params = {}
        for pi, spec in enumerate(seg.pattern):
            kk = jax.random.fold_in(key, si * 100 + pi)
            keys = jax.random.split(kk, seg.repeats)
            seg_p[f"p{pi}"] = jax.vmap(
                lambda k: _init_layer(k, spec, cfg, dtype, n_total))(keys)
        params.append(seg_p)
    return params


def init_stack_cache(segs: List[Segment], cfg: ModelConfig, batch: int,
                     max_seq: int, n_cross: int, dtype) -> List[Params]:
    caches: List[Params] = []
    for seg in segs:
        seg_c: Params = {}
        for pi, spec in enumerate(seg.pattern):
            one = _init_layer_cache(spec, cfg, batch, max_seq, n_cross, dtype)
            seg_c[f"p{pi}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.repeats,) + a.shape), one)
        caches.append(seg_c)
    return caches


def apply_stack(params: List[Params], segs: List[Segment], x: jax.Array,
                cfg: ModelConfig, ctx, *, mode: str,
                caches: Optional[List[Params]] = None,
                index=None, rng=None, decision=None, is_training=True,
                cross_src=None, token_ids=None, token_valid=None,
                flash_decode=False, block_tables=None):
    """Run all segments. Returns (x, new_caches, aux_sum)."""
    new_caches: List[Params] = []
    aux_total = None
    layer_base = 0

    for si, (seg, seg_p) in enumerate(zip(segs, params)):
        npat = len(seg.pattern)

        def pattern_body(x_in, slice_p, slice_c, rep_idx):
            nc_out: Params = {}
            aux_acc = None
            h = x_in
            for pi, spec in enumerate(seg.pattern):
                lrng = (None if rng is None else
                        jax.random.fold_in(rng, layer_base + rep_idx * npat + pi))
                h, nc, aux = _layer_apply(
                    spec, slice_p[f"p{pi}"], h, cfg, ctx, mode=mode,
                    cache=None if slice_c is None else slice_c[f"p{pi}"],
                    index=index, rng=lrng, decision=decision,
                    is_training=is_training, cross_src=cross_src,
                    token_ids=token_ids, token_valid=token_valid,
                    flash_decode=flash_decode, block_tables=block_tables)
                if nc is not None:
                    nc_out[f"p{pi}"] = nc
                aux_acc = aux if aux_acc is None else jax.tree.map(
                    jnp.add, aux_acc, aux)
            return h, nc_out, aux_acc

        if cfg.remat and mode == "train":
            pattern_body = jax.checkpoint(
                pattern_body, static_argnums=(), policy=None)

        seg_c = None if caches is None else caches[si]

        def scan_body(carry, xs):
            x_c = carry
            if cfg.seq_parallel and mode == "train":
                # Megatron-style sequence parallelism: layer-boundary (and
                # remat-saved) activations sharded over the model axis.
                x_c = constrain(x_c, ctx, ("dp", "tp", None))
            if seg_c is not None:
                sp, sc, ri = xs
            else:
                sp, ri = xs
                sc = None
            h, nc, aux = pattern_body(x_c, sp, sc, ri)
            return h, (nc, aux)

        reps = jnp.arange(seg.repeats)
        xs = (seg_p, caches[si], reps) if seg_c is not None else (seg_p, reps)
        if cfg.scan_layers:
            x, (ncs, auxs) = jax.lax.scan(scan_body, x, xs)
        else:
            # unrolled (exact XLA cost_analysis: scan bodies are counted
            # once, not x trip-count — the dry-run unrolls for true costs)
            ys = []
            for r in range(seg.repeats):
                xs_r = jax.tree.map(lambda a: a[r], xs)
                x, y_r = scan_body(x, xs_r)
                ys.append(y_r)
            ncs, auxs = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        if mode in ("prefill", "decode"):
            new_caches.append(ncs)
        aux_sum = jax.tree.map(lambda a: a.sum(0), auxs)
        aux_total = aux_sum if aux_total is None else jax.tree.map(
            jnp.add, aux_total, aux_sum)
        layer_base += seg.repeats * npat

    return x, (new_caches if mode in ("prefill", "decode") else None), aux_total
