"""Memory-bounded flash attention in pure JAX with a custom VJP.

Two-level blocking: outer scan over query chunks, inner scan over KV
chunks, online softmax. The backward recomputes attention probabilities
per (q-chunk, kv-chunk) block from the saved logsumexp — O(L) residual
memory instead of O(L^2) (differentiating through the naive online-softmax
scan would otherwise stash every per-chunk probability block).

Supports: causal masking, sliding window, GQA (KV heads < Q heads),
absolute position offsets. This is also the jnp oracle for the Pallas
flash kernels in repro/kernels.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg), pad


def _block_mask(qpos, kpos, causal, window, lk_real):
    m = (kpos[None, :] < lk_real) & (kpos[None, :] >= 0)
    m = jnp.broadcast_to(m, (qpos.shape[0], kpos.shape[0]))
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_offset: int = 0, kv_offset: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024):
    """q: (B, Lq, H, hd); k, v: (B, Lk, KV, hd). Returns (B, Lq, H, hd)."""
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset,
                             q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset,
                    q_chunk, kv_chunk):
    b, lq, h, hd = q.shape
    lk, kv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]                  # may differ from hd (e.g. MLA 192/128)
    rep = h // kv
    scale = hd ** -0.5
    qp, _ = _pad_axis(q, q_chunk, 1)
    kp, _ = _pad_axis(k, kv_chunk, 1)
    vp, _ = _pad_axis(v, kv_chunk, 1)
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qc = qp.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kc = kp.reshape(b, nk, kv_chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, kv_chunk, kv, hdv).transpose(1, 0, 2, 3, 4)

    def q_block(qi_and_idx):
        qi, i = qi_and_idx
        qf = qi.astype(jnp.float32) * scale
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, xs):
            m, l, acc = carry
            j, kj, vj = xs
            kj = jnp.repeat(kj, rep, 2).astype(jnp.float32)
            vj = jnp.repeat(vj, rep, 2).astype(jnp.float32)
            kpos = kv_offset + j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj)
            mask = _block_mask(qpos, kpos, causal, window, kv_offset + lk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd",
                                                         p, vj)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 2, 1, 3), lse          # (B,Cq,H,hd), (B,H,Cq)

    outs, lses = jax.lax.map(q_block, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hdv)[:, :lq]
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, nq * q_chunk)[:, :, :lq]
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, causal, window, q_offset, kv_offset, q_chunk,
               kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset,
                               q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, kv_offset, q_chunk, kv_chunk,
               res, do):
    q, k, v, out, lse = res
    b, lq, h, hd = q.shape
    lk, kvh = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    rep = h // kvh
    scale = hd ** -0.5
    delta = jnp.einsum("blhd,blhd->bhl", do.astype(jnp.float32),
                       out.astype(jnp.float32))            # (B,H,Lq)
    qp, _ = _pad_axis(q, q_chunk, 1)
    dop, _ = _pad_axis(do, q_chunk, 1)
    lsep, _ = _pad_axis(lse, q_chunk, 2)
    dlt, _ = _pad_axis(delta, q_chunk, 2)
    kp, _ = _pad_axis(k, kv_chunk, 1)
    vp, _ = _pad_axis(v, kv_chunk, 1)
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qc = qp.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    doc = dop.reshape(b, nq, q_chunk, h, hdv).transpose(1, 0, 2, 3, 4)
    lsec = lsep.reshape(b, h, nq, q_chunk).transpose(2, 0, 1, 3)
    dltc = dlt.reshape(b, h, nq, q_chunk).transpose(2, 0, 1, 3)
    kc = kp.reshape(b, nk, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, kv_chunk, kvh, hdv).transpose(1, 0, 2, 3, 4)

    def q_step(carry, xs):
        dk_acc, dv_acc = carry                              # (nk,B,Ck,KV,hd)
        qi, doi, lsei, dlti, i = xs
        qf = qi.astype(jnp.float32)
        dof = doi.astype(jnp.float32)
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_step(dq_acc, xs2):
            j, kj, vj, dkj, dvj = xs2
            ke = jnp.repeat(kj, rep, 2).astype(jnp.float32)
            ve = jnp.repeat(vj, rep, 2).astype(jnp.float32)
            kpos = kv_offset + j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf * scale, ke)
            mask = _block_mask(qpos, kpos, causal, window, kv_offset + lk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])                # (B,H,Cq,Ck)
            dve = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dof, ve)
            ds = p * (dp - dlti[..., None]) * scale
            dq_new = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, ke)
            dke = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
            # collapse expanded heads back to KV heads
            dkj = dkj + dke.reshape(b, kv_chunk, kvh, rep, hd).sum(3)
            dvj = dvj + dve.reshape(b, kv_chunk, kvh, rep, hdv).sum(3)
            return dq_new, (dkj, dvj)

        dq0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)
        dqi, (dk_new, dv_new) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), kc, vc, dk_acc, dv_acc))
        return (dk_new, dv_new), dqi

    dk0 = jnp.zeros((nk, b, kv_chunk, kvh, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, kv_chunk, kvh, hdv), jnp.float32)
    (dkc, dvc), dqc = jax.lax.scan(
        q_step, (dk0, dv0), (qc, doc, lsec, dltc, jnp.arange(nq)))
    dq = dqc.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hd)[:, :lq]
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_chunk, kvh, hd)[:, :lk]
    dv = dvc.transpose(1, 0, 2, 3, 4).reshape(b, nk * kv_chunk, kvh, hdv)[:, :lk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def banded_flash_attention(q, k, v, window: int, q_offset: int = 0,
                           q_chunk: int = 1024, kv_chunk: int = 512,
                           use_full: bool = False):
    """Causal sliding-window attention with BLOCK SKIPPING: each query chunk
    only visits its key band [chunk_start - wpad, chunk_end), so compute is
    O(L * (window + q_chunk)) instead of the masked O(L^2) of plain flash.
    Gradients flow through the per-band flash custom-VJP (O(band) residuals
    per chunk)."""
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    assert q_chunk % kv_chunk == 0
    wpad = -(-window // kv_chunk) * kv_chunk
    qp, _ = _pad_axis(q, q_chunk, 1)
    nq = qp.shape[1] // q_chunk
    # front-pad by wpad (masked via kpos<0), back-pad to cover query padding
    back = max(0, nq * q_chunk - lk)
    kp = jnp.pad(k, ((0, 0), (wpad, back), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (wpad, back), (0, 0), (0, 0)))
    band = wpad + q_chunk

    outs = []
    for i in range(nq):          # nq static; offsets stay static for the vjp
        qi = qp[:, i * q_chunk:(i + 1) * q_chunk]
        ks = kp[:, i * q_chunk:i * q_chunk + band]
        vs = vp[:, i * q_chunk:i * q_chunk + band]
        if use_full:             # cost-accounting mode: exact FLOP counting
            from repro.models.attention import full_attention
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            kpos = q_offset + i * q_chunk - wpad + jnp.arange(band)
            outs.append(full_attention(qi, ks, vs, causal=True,
                                       window=window, qpos=qpos, kpos=kpos))
        else:
            outs.append(flash_attention(qi, ks, vs, True, window,
                                        q_offset + i * q_chunk,
                                        q_offset + i * q_chunk - wpad,
                                        q_chunk, kv_chunk))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :lq]
