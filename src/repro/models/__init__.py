from repro.models.model import (decode_step, init_cache, init_model,
                                model_apply, prefill)
from repro.models.transformer import LayerSpec, Segment, layer_plan

__all__ = ["LayerSpec", "Segment", "decode_step", "init_cache", "init_model",
           "layer_plan", "model_apply", "prefill"]
