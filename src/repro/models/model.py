"""Top-level model: embeddings + (optional encoder) + decoder stack + head.

Public API:
  init_model(key, cfg)                          -> params
  model_apply(params, batch, cfg, ctx, ...)     -> (logits, aux)        [train]
  prefill(params, batch, cfg, ctx, max_seq)     -> (logits, caches)
  decode_step(params, caches, token, index,...) -> (logits, caches)
  init_cache(cfg, batch, max_seq, dtype)        -> caches

``batch`` keys: "tokens" (B, L) always; plus per family:
  vlm    : "img_embeds"  (B, n_img, d_image)   [stub vision encoder output]
  encdec : "frames" (B, S_enc, d_model) for audio (stub conv frontend), or
           "enc_tokens" (B, S_enc) for text enc-dec (the paper's MT models).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import ParallelContext
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


from repro.models.transformer import constrain as _constrain


def _zero_aux(cfg: ModelConfig):
    from repro.core.moe import _zero_aux as moe_zero_aux
    return moe_zero_aux(cfg.moe.n_experts if cfg.moe is not None else 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key: jax.Array, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    segs = T.layer_plan(cfg)
    n_total = cfg.n_layers + (cfg.encdec.n_encoder_layers if cfg.encdec else 0)
    ks = jax.random.split(key, 10)
    p: Params = {
        "embed": L.init_embed(ks[0], cfg.vocab, cfg.d_model, dtype),
        "decoder": T.init_stack(ks[1], segs, cfg, dtype, n_total),
        "final_norm": L.init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            ks[2], (cfg.d_model, cfg.vocab), dtype) * (cfg.d_model ** -0.5)
    if cfg.encdec is not None:
        enc_segs = T.layer_plan(cfg, encoder=True)
        p["encoder"] = T.init_stack(ks[3], enc_segs, cfg, dtype, n_total)
        p["enc_final_norm"] = L.init_norm(cfg, cfg.d_model, dtype)
    if cfg.vlm is not None:
        p["img_proj"] = jax.random.normal(
            ks[4], (cfg.vlm.d_image, cfg.d_model), dtype) * (cfg.vlm.d_image ** -0.5)
    if cfg.hybrid is not None:
        p["meta"] = jax.random.normal(
            ks[5], (cfg.hybrid.n_meta_tokens, cfg.d_model), dtype) * 0.02
    if cfg.mtp:
        spec = T.LayerSpec(mixer="mla" if cfg.mla is not None else "gqa",
                           moe=False)
        p["mtp"] = {
            "proj": jax.random.normal(ks[6], (2 * cfg.d_model, cfg.d_model),
                                      dtype) * ((2 * cfg.d_model) ** -0.5),
            "norm_h": L.init_norm(cfg, cfg.d_model, dtype),
            "norm_e": L.init_norm(cfg, cfg.d_model, dtype),
            "block": T._init_layer(ks[7], spec, cfg, dtype, n_total),
            "norm_out": L.init_norm(cfg, cfg.d_model, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def _encode(params: Params, batch: Dict, cfg: ModelConfig, ctx, *,
            rng, decision, is_training):
    enc_segs = T.layer_plan(cfg, encoder=True)
    if "frames" in batch:                      # audio stub frontend output
        x = batch["frames"].astype(cfg.dtype)
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
        tok = None
    else:
        tok = batch["enc_tokens"]
        x = L.embed_apply(params["embed"], tok).astype(cfg.dtype)
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    x, _, aux = T.apply_stack(params["encoder"], enc_segs, x, cfg, ctx,
                              mode="train", rng=rng, decision=decision,
                              is_training=is_training, token_ids=tok)
    return L.norm_apply(params["enc_final_norm"], x, cfg), aux


def _cross_source(params: Params, batch: Dict, cfg: ModelConfig, ctx, *,
                  rng, decision, is_training):
    """Returns (cross_src, aux) for families that cross-attend."""
    if cfg.encdec is not None:
        return _encode(params, batch, cfg, ctx, rng=rng, decision=decision,
                       is_training=is_training)
    if cfg.vlm is not None:
        img = batch["img_embeds"].astype(cfg.dtype)
        return (img.astype(params["img_proj"].dtype) @ params["img_proj"]
                ).astype(cfg.dtype), None
    return None, None


# ---------------------------------------------------------------------------
# forward (train) / prefill / decode
# ---------------------------------------------------------------------------

def _logits(params: Params, x: jax.Array, cfg: ModelConfig,
            ctx: Optional[ParallelContext] = None) -> jax.Array:
    x = x.astype(jnp.dtype(cfg.param_dtype))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    # keep logits vocab-sharded over `model`: the (B, L, V) f32 tensor is by
    # far the largest activation for big-vocab archs
    return _constrain(logits, ctx, ("dp", None, "tp"))


def model_apply(params: Params, batch: Dict, cfg: ModelConfig,
                ctx: Optional[ParallelContext] = None, *,
                rng: Optional[jax.Array] = None, decision=None,
                is_training: bool = True,
                return_hidden: bool = False) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward, logits for every position.

    ``return_hidden=True`` returns the final-norm hidden states instead of
    logits (the training loss computes a CHUNKED cross-entropy so the full
    (B, L, V) f32 logits tensor never materializes)."""
    tokens = batch["tokens"]
    segs = T.layer_plan(cfg)
    x = L.embed_apply(params["embed"], tokens).astype(cfg.dtype)
    x = _constrain(x, ctx, ("dp", None, None))
    n_meta = 0
    if cfg.hybrid is not None:
        n_meta = cfg.hybrid.n_meta_tokens
        meta = jnp.broadcast_to(params["meta"].astype(cfg.dtype)[None],
                                (x.shape[0],) + params["meta"].shape)
        x = jnp.concatenate([meta, x], axis=1)
    cross_src, enc_aux = _cross_source(params, batch, cfg, ctx, rng=rng,
                                       decision=decision,
                                       is_training=is_training)
    x, _, aux = T.apply_stack(params["decoder"], segs, x, cfg, ctx,
                              mode="train", rng=rng, decision=decision,
                              is_training=is_training, cross_src=cross_src,
                              token_ids=tokens if n_meta == 0 else None)
    if n_meta:
        x = x[:, n_meta:]
    x = L.norm_apply(params["final_norm"], x, cfg)
    if enc_aux is not None:
        aux = jax.tree.map(jnp.add, aux, enc_aux)
    if cfg.mtp and is_training:
        aux = dict(aux)
        aux["mtp_hidden"] = _mtp_hidden(params, x, tokens, cfg, ctx, rng,
                                        decision, is_training)
    if return_hidden:
        return x, aux
    return _logits(params, x, cfg, ctx), aux


def _mtp_hidden(params, h, tokens, cfg, ctx, rng, decision, is_training):
    """DeepSeek-V3 multi-token prediction (depth 1): predict token t+2 from
    the main trunk state at t and the embedding of token t+1. Returns the
    MTP hidden states (head applied chunked in the loss)."""
    mtp = params["mtp"]
    emb_next = L.embed_apply(params["embed"],
                             jnp.roll(tokens, -1, axis=1)).astype(cfg.dtype)
    hh = L.norm_apply(mtp["norm_h"], h, cfg)
    ee = L.norm_apply(mtp["norm_e"], emb_next, cfg)
    z = jnp.concatenate([hh, ee], axis=-1)
    z = (z.astype(mtp["proj"].dtype) @ mtp["proj"]).astype(cfg.dtype)
    spec = T.LayerSpec(mixer="mla" if cfg.mla is not None else "gqa", moe=False)
    z, _, _ = T._layer_apply(spec, mtp["block"], z, cfg, ctx, mode="train",
                             cache=None, index=None, rng=rng,
                             decision=decision, is_training=is_training,
                             cross_src=None, token_ids=None)
    return L.norm_apply(mtp["norm_out"], z, cfg)


def head_matrix(params: Params, cfg: ModelConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> List[Params]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    segs = T.layer_plan(cfg)
    n_meta = cfg.hybrid.n_meta_tokens if cfg.hybrid is not None else 0
    n_cross = 0
    if cfg.encdec is not None:
        n_cross = cfg.encdec.encoder_seq
    elif cfg.vlm is not None:
        n_cross = cfg.vlm.n_image_tokens
    return T.init_stack_cache(segs, cfg, batch, max_seq + n_meta, n_cross,
                              dtype)


def prefill(params: Params, batch: Dict, cfg: ModelConfig,
            ctx: Optional[ParallelContext] = None, *,
            max_seq: Optional[int] = None,
            rng: Optional[jax.Array] = None,
            last_index: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, List[Params]]:
    """``last_index`` (B,) selects the per-row position whose logits are
    returned instead of the default last column — the bucketed-prefill path
    (serve/scheduler.py) right-pads prompts to a shared length and reads
    each row's logits at its true last prompt token. Causal masking keeps
    positions < last_index[b] independent of the padding."""
    tokens = batch["tokens"]
    b, l = tokens.shape
    max_seq = max_seq or cfg.max_seq
    segs = T.layer_plan(cfg)
    caches = init_cache(cfg, b, max_seq)
    x = L.embed_apply(params["embed"], tokens).astype(cfg.dtype)
    n_meta = 0
    if cfg.hybrid is not None:
        n_meta = cfg.hybrid.n_meta_tokens
        meta = jnp.broadcast_to(params["meta"].astype(cfg.dtype)[None],
                                (b,) + params["meta"].shape)
        x = jnp.concatenate([meta, x], axis=1)
    cross_src, _ = _cross_source(params, batch, cfg, ctx, rng=rng,
                                 decision=False, is_training=False)
    x, caches, _ = T.apply_stack(params["decoder"], segs, x, cfg, ctx,
                                 mode="prefill", caches=caches, rng=rng,
                                 decision=False, is_training=False,
                                 cross_src=cross_src,
                                 token_ids=tokens if n_meta == 0 else None)
    if n_meta:
        x = x[:, n_meta:]
    x = L.norm_apply(params["final_norm"], x, cfg)
    if last_index is not None:
        x_last = jnp.take_along_axis(
            x, last_index.astype(jnp.int32)[:, None, None], axis=1)
    else:
        x_last = x[:, -1:]
    return _logits(params, x_last, cfg, ctx), caches


def decode_step(params: Params, caches: List[Params], token: jax.Array,
                index, cfg: ModelConfig,
                ctx: Optional[ParallelContext] = None, *,
                rng: Optional[jax.Array] = None,
                local_routing: bool = False,
                token_valid: Optional[jax.Array] = None,
                flash_decode: bool = False,
                block_tables: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, List[Params]]:
    """token: (B, 1) int32; index: absolute position of this token — scalar,
    or (B,) for slot-pool decode where every row sits at its own position.
    Gating Dropout is off at inference (paper §3: p=0, no rescaling), but
    ``local_routing=True`` reuses its LOCAL routing path as a static
    decision: MoE tokens route within the local expert group only, so the
    sharded backend's decode executable contains no all-to-all (DESIGN.md
    §9). ``token_valid`` (B,) masks rows (retired/empty pool slots) out of
    expert-capacity competition. ``flash_decode=True`` routes full-cache
    attention reads through the kernels.flash_decode Pallas kernel.
    ``block_tables`` (B, n_blocks) switches full-length attention caches
    to paged (page-arena) addressing (DESIGN.md §13); positions in the
    table are META-INCLUSIVE logical positions — the same space as ``idx``
    below — so callers build tables over ``max_seq + n_meta`` positions."""
    segs = T.layer_plan(cfg)
    x = L.embed_apply(params["embed"], token).astype(cfg.dtype)
    n_meta = cfg.hybrid.n_meta_tokens if cfg.hybrid is not None else 0
    idx = index + n_meta
    if token_valid is not None and token_valid.ndim == 1:
        token_valid = token_valid[:, None]            # (B,) -> (B, L=1)
    x, caches, _ = T.apply_stack(params["decoder"], segs, x, cfg, ctx,
                                 mode="decode", caches=caches, index=idx,
                                 rng=rng, decision=bool(local_routing),
                                 is_training=False, token_ids=token,
                                 token_valid=token_valid,
                                 flash_decode=flash_decode,
                                 block_tables=block_tables)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return _logits(params, x, cfg, ctx), caches
