"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Low-rank joint compression of K/V into a latent c_kv plus a decoupled
shared RoPE key. Decode uses the ABSORBED formulation: the up-projections
are folded into the query/output so the per-step cost reads only the
compressed cache (B, S, kv_lora + rope_dim) — the reason MLA's decode
memory term is ~an order of magnitude below GQA at the same head count.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope

Params = Dict[str, Any]
NEG_INF = -1e30


def init_mla(key: jax.Array, cfg: ModelConfig, dtype,
             out_scale: float = 1.0) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "w_dq": jax.random.normal(k1, (d, m.q_lora_rank), dtype) * d ** -0.5,
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": jax.random.normal(k2, (m.q_lora_rank, h, dn + dr), dtype)
                * m.q_lora_rank ** -0.5,
        "w_dkv": jax.random.normal(k3, (d, m.kv_lora_rank + dr), dtype) * d ** -0.5,
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_ukv": jax.random.normal(k4, (m.kv_lora_rank, h, dn + dv), dtype)
                 * m.kv_lora_rank ** -0.5,
        "wo": jax.random.normal(k5, (h, dv, d), dtype)
              * ((h * dv) ** -0.5) * out_scale,
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _project_q(p: Params, x: jax.Array, cfg: ModelConfig, pos: jax.Array):
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = _rms(x.astype(p["w_dq"].dtype) @ p["w_dq"], p["q_norm"])
    q = jnp.einsum("blc,chk->blhk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p: Params, x: jax.Array, cfg: ModelConfig, pos: jax.Array):
    """x -> (c_kv normed (B,L,c), k_rope roped (B,L,dr)). This pair IS the cache."""
    m = cfg.mla
    ckv_full = x.astype(p["w_dkv"].dtype) @ p["w_dkv"]
    c_kv = _rms(ckv_full[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(ckv_full[..., None, m.kv_lora_rank:], pos,
                        cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  q_offset: int = 0, chunk: int = 2048,
                  return_cache: bool = False):
    """Training/prefill path: decompress K/V and run standard causal MHA
    (chunked over KV to stay memory-bounded)."""
    m = cfg.mla
    b, l, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    pos = q_offset + jnp.arange(l)
    q_nope, q_rope = _project_q(p, x, cfg, pos)
    c_kv, k_rope = _compress_kv(p, x, cfg, pos)
    kv = jnp.einsum("blc,chk->blhk", c_kv, p["w_ukv"])
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :], (b, l, h, dr))], -1)
    from repro.models.attention import flash_attention
    o = flash_attention(q, k, v, causal=True, q_offset=q_offset, chunk=chunk)
    y = jnp.einsum("blhv,hvd->bld", o.astype(p["wo"].dtype),
                   p["wo"]).astype(x.dtype)
    if return_cache:
        return y, (c_kv, k_rope)
    return y


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Params:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
               index: jax.Array,
               block_tables=None) -> Tuple[jax.Array, Params]:
    """Absorbed one-token decode against the compressed cache. ``index`` is
    a scalar, or a (B,) vector for slot-pool decode (per-row positions).

    ``block_tables`` (B, n_blocks) switches to PAGED addressing (DESIGN.md
    §13): the cache leaves are then page arenas ``(n_pages + 1, page_size,
    c | dr)`` shared by all rows. The latent pair is written through the
    table and the row's pages gathered back into a contiguous view; the
    ``pos <= index`` mask zeroes everything past each row's depth exactly,
    so the paged read is bitwise equal to the slot-row read."""
    m = cfg.mla
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    index = jnp.asarray(index)
    per_row = index.ndim == 1
    pos = index[:, None] if per_row else index[None]
    q_nope, q_rope = _project_q(p, x, cfg, pos)            # (B,1,H,dn/(dr))
    c_new, kr_new = _compress_kv(p, x, cfg, pos)           # (B,1,c), (B,1,dr)
    smax = cache["c_kv"].shape[1]
    if block_tables is not None:
        assert per_row, "paged decode requires per-row positions"
        ps = smax                                # arena: (P+1, ps, c | dr)
        nb = block_tables.shape[1]
        b = x.shape[0]
        page = jnp.take_along_axis(block_tables, (index // ps)[:, None],
                                   axis=1)[:, 0]
        off = index % ps
        c_arena = cache["c_kv"].at[page, off].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        r_arena = cache["k_rope"].at[page, off].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
        c_kv = c_arena[block_tables].reshape(b, nb * ps, -1)
        k_rope = r_arena[block_tables].reshape(b, nb * ps, -1)
        valid = jnp.arange(nb * ps)[None, :] <= index[:, None]    # (B, S)
        new_cache = {"c_kv": c_arena, "k_rope": r_arena}
    elif per_row:
        rows = jnp.arange(x.shape[0])
        c_kv = cache["c_kv"].at[rows, index].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, index].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
        valid = jnp.arange(smax)[None, :] <= index[:, None]       # (B, S)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, index, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
            (0, index, 0))
        valid = jnp.broadcast_to(jnp.arange(smax) <= index,
                                 (x.shape[0], smax))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    # absorb W_ukv(K) into the query
    w_k = p["w_ukv"][..., :dn]                             # (c, H, dn)
    w_v = p["w_ukv"][..., dn:]                             # (c, H, dv)
    q_abs = jnp.einsum("blhn,chn->blhc", q_nope, w_k)      # (B,1,H,c)
    s = (jnp.einsum("blhc,bsc->bhls", q_abs.astype(jnp.float32),
                    c_kv.astype(jnp.float32))
         + jnp.einsum("blhr,bsr->bhls", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * ((dn + dr) ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhls,bsc->blhc", w, c_kv.astype(jnp.float32))
    o = jnp.einsum("blhc,chv->blhv", lat, w_v.astype(jnp.float32))
    y = jnp.einsum("blhv,hvd->bld", o.astype(p["wo"].dtype), p["wo"])
    return y.astype(x.dtype), new_cache
