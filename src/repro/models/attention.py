"""Attention: GQA with RoPE, full / chunked-flash (online softmax) paths,
sliding-window support, decode against full or ring-buffer KV caches.

Shapes: q (B, Lq, H, hd); k, v (B, Lk, KV, hd) with H % KV == 0.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope

Params = Dict[str, Any]
NEG_INF = -1e30


def _expand_kv(k: jax.Array, h: int) -> jax.Array:
    """(B, L, KV, hd) -> (B, L, H, hd) by repeating groups."""
    b, l, kv, hd = k.shape
    if kv == h:
        return k
    return jnp.repeat(k, h // kv, axis=2)


def _mask(qpos: jax.Array, kpos: jax.Array, causal: bool,
          window: int) -> jax.Array:
    """(Lq, Lk) boolean validity mask from absolute positions."""
    m = jnp.broadcast_to(kpos[None, :] >= 0,
                         (qpos.shape[0], kpos.shape[0]))
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   qpos: Optional[jax.Array] = None,
                   kpos: Optional[jax.Array] = None,
                   kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Quadratic attention. kv_valid: (B, Lk) or (Lk,) extra validity."""
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    if qpos is None:
        qpos = jnp.arange(lq)
    if kpos is None:
        kpos = jnp.arange(lk)
    ke = _expand_kv(k, h)
    ve = _expand_kv(v, h)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   ke.astype(jnp.float32)) * (hd ** -0.5)
    m = _mask(qpos, kpos, causal, window)                 # (Lq, Lk)
    if kv_valid is not None:
        kv_valid = jnp.asarray(kv_valid)
        if kv_valid.ndim == 1:
            m = m & kv_valid[None, :]
            s = jnp.where(m[None, None], s, NEG_INF)
        else:
            mm = m[None, None] & kv_valid[:, None, None, :]
            s = jnp.where(mm, s, NEG_INF)
    else:
        s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, ve.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, chunk: int = 1024) -> jax.Array:
    """Memory-bounded blocked attention. Small sequences take the quadratic
    path; larger ones the two-level-blocked custom-VJP flash implementation
    (repro.models.flash) whose backward recomputes probability blocks —
    O(L) residuals instead of O(L^2)."""
    lq, lk = q.shape[1], k.shape[1]
    if lk <= 2 * chunk:
        return full_attention(q, k, v, causal=causal, window=window,
                              qpos=q_offset + jnp.arange(lq))
    from repro.models.flash import flash_attention as _flash
    return _flash(q, k, v, causal, window, q_offset, 0,
                  min(chunk, lq), chunk)


# ---------------------------------------------------------------------------
# GQA projection layer
# ---------------------------------------------------------------------------

def init_attn(key: jax.Array, cfg: ModelConfig, dtype,
              out_scale: float = 1.0) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    kq, kk, kv_, ko = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(kq, (d, h, hd), dtype) * s,
        "wk": jax.random.normal(kk, (d, kv, hd), dtype) * s,
        "wv": jax.random.normal(kv_, (d, kv, hd), dtype) * s,
        "wo": jax.random.normal(ko, (h, hd, d), dtype) * ((h * hd) ** -0.5) * out_scale,
    }


def attn_qkv(p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    xc = x.astype(p["wq"].dtype)
    q = jnp.einsum("bld,dhk->blhk", xc, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", xc, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", xc, p["wv"])
    return q, k, v


def attn_out(p: Params, o: jax.Array, x_dtype) -> jax.Array:
    return jnp.einsum("blhk,hkd->bld", o.astype(p["wo"].dtype),
                      p["wo"]).astype(x_dtype)


def self_attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
                   causal: bool = True, window: int = 0, q_offset: int = 0,
                   use_rope: bool = True, chunk: int = 1024) -> jax.Array:
    q, k, v = attn_qkv(p, x)
    if use_rope:
        pos = q_offset + jnp.arange(x.shape[1])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        q_offset=q_offset, chunk=chunk)
    return attn_out(p, o, x.dtype)


# ---------------------------------------------------------------------------
# KV caches (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype) -> Params:
    """Full cache, or ring buffer when the layer uses sliding-window."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((batch, max_seq, kv, hd), dtype),
    }


def init_ring_cache(cfg: ModelConfig, batch: int, window: int,
                    dtype) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, window, kv, hd), dtype),
        "v": jnp.zeros((batch, window, kv, hd), dtype),
        "pos": jnp.full((window,), -1, jnp.int32),   # absolute position per slot
    }


def decode_self_attention(p: Params, x: jax.Array, cache: Params,
                          cfg: ModelConfig, index: jax.Array, *,
                          window: int = 0, use_rope: bool = True,
                          flash: bool = False,
                          block_tables: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Params]:
    """One-token decode. x: (B, 1, d); ``index`` = absolute position of the
    new token — a scalar (all rows at the same position) or a (B,) vector
    (slot-pool decode: every row at its own position). Ring-buffer cache
    when `window`>0 (cache length == window), else full cache written at
    `index`. The per-row path requires the ring ``pos`` leaf batched to
    (B, window) (``repro.serve.engine.init_slot_pool`` builds such caches);
    masks are identical in value to the scalar path, so the two paths emit
    bitwise-equal outputs when every row shares one position.

    ``flash=True`` routes the FULL-cache read through the
    ``kernels.flash_decode`` online-softmax kernel (per-row index
    supported) — the position mask ``pos <= index`` is the same predicate
    as the reference path's ``kv_valid``, so unwritten cache rows beyond
    each row's depth never contribute. Ring-buffer (windowed) layers keep
    the reference path: their validity depends on the ``pos`` leaf, not a
    prefix mask.

    ``block_tables`` (B, n_blocks) switches the full-cache path to PAGED
    addressing (DESIGN.md §13): ``cache["k"]/["v"]`` are then a physical
    page arena ``(n_pages + 1, page_size, KV, hd)`` shared by all B rows,
    and row b's logical position p lives at arena slot
    ``[block_tables[b, p // page_size], p % page_size]``. The new token is
    written through the table, then the row's pages are gathered back into
    a contiguous (B, n_blocks * page_size, ...) view guarded by the same
    ``pos <= index`` predicate — positions past ``index`` (unwritten tail,
    other requests' stale bytes on the scratch page) are masked to
    exact-zero probability, so paged and slot-row reads are bitwise equal.
    Requires per-row ``index``; windowed layers ignore the table (their
    ring stays slot-addressed)."""
    index = jnp.asarray(index)
    per_row = index.ndim == 1
    b = x.shape[0]
    q, k, v = attn_qkv(p, x)
    if use_rope:
        pos = index[:, None] if per_row else index[None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k = k.astype(cache["k"].dtype)
    v = v.astype(cache["v"].dtype)
    rows = jnp.arange(b)
    if window > 0 and cache["k"].shape[1] == window:
        slot = jnp.mod(index, window)
        if per_row:
            assert cache["pos"].ndim == 2, \
                "per-row decode needs a slot-pool ring cache (batched pos)"
            ck = cache["k"].at[rows, slot].set(k[:, 0])
            cv = cache["v"].at[rows, slot].set(v[:, 0])
            cpos = cache["pos"].at[rows, slot].set(index.astype(jnp.int32))
            valid = ((cpos >= 0) & (cpos > index[:, None] - window)
                     & (cpos <= index[:, None]))
            o = full_attention(q, ck, cv, causal=False, kv_valid=valid)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(
                cache["pos"], jnp.asarray(index)[None].astype(jnp.int32),
                (slot,))
            valid = (cpos >= 0) & (cpos > index - window) & (cpos <= index)
            o = full_attention(q, ck, cv, causal=False,
                               qpos=jnp.asarray(index)[None],
                               kpos=jnp.maximum(cpos, 0), kv_valid=valid)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    elif block_tables is not None:
        assert per_row, "paged decode requires per-row positions"
        ps = cache["k"].shape[1]
        nb = block_tables.shape[1]
        page = jnp.take_along_axis(block_tables, (index // ps)[:, None],
                                   axis=1)[:, 0]
        off = index % ps
        ck = cache["k"].at[page, off].set(k[:, 0])
        cv = cache["v"].at[page, off].set(v[:, 0])
        if flash:
            from repro.kernels import flash_decode_paged
            o = flash_decode_paged(q[:, 0], ck, cv, block_tables,
                                   index)[:, None]
        else:
            gk = ck[block_tables].reshape((b, nb * ps) + ck.shape[2:])
            gv = cv[block_tables].reshape((b, nb * ps) + cv.shape[2:])
            valid = jnp.arange(nb * ps)[None, :] <= index[:, None]
            o = full_attention(q, gk, gv, causal=False, kv_valid=valid)
        new_cache = {"k": ck, "v": cv}
    else:
        s = cache["k"].shape[1]
        if per_row:
            ck = cache["k"].at[rows, index].set(k[:, 0])
            cv = cache["v"].at[rows, index].set(v[:, 0])
            if flash:
                from repro.kernels import flash_decode
                o = flash_decode(q[:, 0], ck, cv, index)[:, None]
            else:
                valid = jnp.arange(s)[None, :] <= index[:, None]
                o = full_attention(q, ck, cv, causal=False, kv_valid=valid)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, index, 0, 0))
            if flash:
                from repro.kernels import flash_decode
                o = flash_decode(q[:, 0], ck, cv, index)[:, None]
            else:
                kpos = jnp.arange(s)
                valid = kpos <= index
                o = full_attention(q, ck, cv, causal=False,
                                   qpos=jnp.asarray(index)[None],
                                   kpos=kpos, kv_valid=valid)
        new_cache = {"k": ck, "v": cv}
    return attn_out(p, o, x.dtype), new_cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec, VLM)
# ---------------------------------------------------------------------------

def init_cross_attn(key: jax.Array, cfg: ModelConfig, dtype,
                    kv_dim: Optional[int] = None,
                    out_scale: float = 1.0) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_
    kvd = kv_dim or d
    kq, kk, kv_, ko = jax.random.split(key, 4)
    return {
        "wq": jax.random.normal(kq, (d, h, hd), dtype) * (d ** -0.5),
        "wk": jax.random.normal(kk, (kvd, h, hd), dtype) * (kvd ** -0.5),
        "wv": jax.random.normal(kv_, (kvd, h, hd), dtype) * (kvd ** -0.5),
        "wo": jax.random.normal(ko, (h, hd, d), dtype) * ((h * hd) ** -0.5) * out_scale,
    }


def make_cross_kv(p: Params, kv_src: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bld,dhk->blhk", kv_src.astype(p["wk"].dtype), p["wk"])
    v = jnp.einsum("bld,dhk->blhk", kv_src.astype(p["wv"].dtype), p["wv"])
    return k, v


def cross_attention_kv(p: Params, x: jax.Array, k: jax.Array,
                       v: jax.Array) -> jax.Array:
    q = jnp.einsum("bld,dhk->blhk", x.astype(p["wq"].dtype), p["wq"])
    o = full_attention(q, k, v, causal=False)
    return attn_out(p, o, x.dtype)


def cross_attention(p: Params, x: jax.Array, kv_src: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """x: (B, Lq, d) queries; kv_src: (B, Lk, kv_dim) encoder/image states."""
    k, v = make_cross_kv(p, kv_src)
    return cross_attention_kv(p, x, k, v)
