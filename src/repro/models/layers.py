"""Basic layers: norms, embeddings, RoPE, dense FFN. Functional style:
``init_*`` builds a param pytree, ``*_apply`` consumes it."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: Params, x: jax.Array, cfg: ModelConfig,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5)


def embed_apply(embed: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(embed, ids, axis=0)


def sinusoidal_pos(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d - d // 2)]))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, hd); positions: (L,) or (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                          # (..., L, 1, hd/2)
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense FFN (the non-MoE sub-layer; also the "shared expert" body)
# ---------------------------------------------------------------------------

def init_ffn(key: jax.Array, d: int, dff: int, cfg: ModelConfig, dtype,
             out_scale: float = 1.0) -> Params:
    k_i, k_g, k_o = jax.random.split(key, 3)
    p = {
        "w_in": jax.random.normal(k_i, (d, dff), dtype) * (d ** -0.5),
        "w_out": jax.random.normal(k_o, (dff, d), dtype) * (dff ** -0.5) * out_scale,
    }
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(k_g, (d, dff), dtype) * (d ** -0.5)
    return p


def ffn_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xc = x.astype(p["w_in"].dtype)
    h = xc @ p["w_in"]
    if cfg.gated_mlp:
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(xc @ p["w_gate"]) * h
    else:
        h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    return (h @ p["w_out"]).astype(x.dtype)
