"""Portable host-transfer guard (DESIGN.md §12, host-sync pass).

``jax.transfer_guard_device_to_host`` is a no-op on the CPU backend
(device buffers ARE host buffers, so the zero-copy path never trips
it) — useless on the 8-device CPU mesh this repo's CI runs on. This
guard intercepts the Python-level sync points instead: the jax.Array
scalar dunders (``float()``, ``int()``, ``bool()``, ``.item()``) and
the numpy conversion entry points (``np.asarray`` & co.) — on CPU,
numpy reads a jax array through the C buffer protocol without ever
calling ``__array__``, so the numpy FUNCTIONS are wrapped, not just
the dunder. Explicit ``jax.device_get`` stays sanctioned, matching the
native guard's implicit/explicit split, so code that means to sync
says so.

Events record the first repo frame that triggered the pull, so a
finding points at scheduler.py:NNN, not at numpy internals.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import traceback
from typing import Callable, List, Optional, Tuple

__all__ = ["TransferEvent", "guard_host_transfers", "jit_cache_sizes"]

_HOOKS = ("__array__", "__float__", "__int__", "__index__", "__bool__",
          "item")
# numpy entry points that pull device buffers host-side (via the buffer
# protocol, invisibly to __array__) when handed a jax Array
_NP_FUNCS = ("asarray", "array", "asanyarray", "ascontiguousarray",
             "stack", "concatenate")

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class TransferEvent:
    method: str              # which dunder pulled the value
    origin: str              # "path/file.py:lineno (func)" of the caller
    sanctioned: bool         # inside an explicit jax.device_get
    internal: bool           # triggered by jax machinery (const lowering,
                             # dispatch plumbing) — not a user-code sync


def _caller_origin():
    """(origin, internal): origin is the first stack frame outside this
    module / jax / numpy internals; internal is True when the INNERMOST
    real frame is jax's own machinery (e.g. np.asarray of a captured
    constant during lowering) rather than repo/user code."""
    stack = traceback.extract_stack()
    internal = None
    origin = "<unknown>"
    for frame in reversed(stack):
        f = frame.filename
        if "analysis/hostsync" in f:
            continue
        if internal is None:
            internal = "/jax/" in f or "jax_plugins" in f
        if "/jax/" in f or "/numpy/" in f or "jax_plugins" in f:
            continue
        origin = f"{f}:{frame.lineno} ({frame.name})"
        break
    return origin, bool(internal)


@contextlib.contextmanager
def guard_host_transfers(*, mode: str = "record",
                         events: Optional[List[TransferEvent]] = None):
    """Intercept implicit jax.Array device->host pulls.

    mode="record": append a TransferEvent per pull to ``events`` and let
    it proceed (the lint pass classifies afterwards).
    mode="raise": raise RuntimeError on the first UNsanctioned pull (the
    conftest fixture's enforcement mode).

    Yields the event list. Explicit ``jax.device_get`` calls are wrapped
    to mark their pulls sanctioned. Re-entrant within a thread; patches
    are process-global while active, but recording is per-call."""
    import jax
    from jax._src.array import ArrayImpl

    assert mode in ("record", "raise"), mode
    evs: List[TransferEvent] = events if events is not None else []

    def _hit(method: str):
        sanctioned = getattr(_state, "sanctioned", 0) > 0
        origin, internal = _caller_origin()
        ev = TransferEvent(method=method, origin=origin,
                           sanctioned=sanctioned, internal=internal)
        evs.append(ev)
        if mode == "raise" and not (sanctioned or internal):
            raise RuntimeError(
                f"implicit device->host transfer via {method} at "
                f"{ev.origin}; use jax.device_get for intentional syncs "
                f"(analysis.hostsync guard)")

    saved = {}
    for name in _HOOKS:
        orig = getattr(ArrayImpl, name, None)
        if orig is None:
            continue
        saved[name] = orig

        def wrapper(self, *a, _orig=orig, _name=name, **kw):
            _hit(_name)
            return _orig(self, *a, **kw)

        setattr(ArrayImpl, name, wrapper)

    import numpy as np

    def _holds_device_array(obj, depth=2):
        if isinstance(obj, ArrayImpl):
            return True
        if depth and isinstance(obj, (list, tuple)):
            return any(_holds_device_array(o, depth - 1) for o in obj)
        return False

    saved_np = {}
    for fname in _NP_FUNCS:
        nf = getattr(np, fname, None)
        if nf is None:
            continue
        saved_np[fname] = nf

        def np_wrapper(*a, _orig=nf, _name=fname, **kw):
            if any(_holds_device_array(x) for x in a):
                _hit(f"np.{_name}")
            return _orig(*a, **kw)

        setattr(np, fname, np_wrapper)

    orig_get = jax.device_get

    def sanctioned_get(x):
        _state.sanctioned = getattr(_state, "sanctioned", 0) + 1
        try:
            return orig_get(x)
        finally:
            _state.sanctioned -= 1

    jax.device_get = sanctioned_get
    try:
        yield evs
    finally:
        jax.device_get = orig_get
        for name, orig in saved.items():
            setattr(ArrayImpl, name, orig)
        for fname, orig in saved_np.items():
            setattr(np, fname, orig)


def jit_cache_sizes(fns) -> Tuple[int, ...]:
    """Compiled-variant counts of jitted callables — the cache-miss
    detector's snapshot primitive. A steady-state serving/training loop
    must not grow any of these between ticks (a growth means a tick
    re-traced: a shape leak, a weak-type flip, a python-hash dependency)."""
    sizes = []
    for fn in fns:
        size = getattr(fn, "_cache_size", None)
        sizes.append(int(size()) if callable(size) else -1)
    return tuple(sizes)
