"""Lint pass registry (DESIGN.md §12).

Mirrors the PR-1 backend / PR-5 substrate registries: passes register
under an id via ``@register_pass`` and run against every executable in
the registry (``analysis/executables.py``) whose spec opts in by
carrying an expectation for that pass. A pass returns Findings — never
raises on a violation — so one broken invariant doesn't mask the rest
of the report; the gate aggregates afterwards.

Suppression: a spec can carry ``ignore=("pass-id", ...)`` (written in
the registry as a trailing ``# lint: ignore[pass-id]`` comment on the
registration line — ``register_executable`` parses it from source).
Suppressed findings stay in the report flagged ``suppressed`` but do
not fail the gate.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

__all__ = ["Finding", "LintPass", "available_passes", "get_pass",
           "register_pass", "run_pass"]

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_id: str
    severity: str            # error | warning | info
    executable: str
    location: str            # "computation/%instr", "jaxpr:scan/pjit", ...
    message: str
    suppressed: bool = False

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class LintPass:
    pass_id: str
    doc: str
    fn: Callable              # fn(spec, artifacts) -> List[Finding]
    needs: Tuple[str, ...]    # artifact kinds: "hlo" | "jaxpr" | "scenario"


_REGISTRY: Dict[str, LintPass] = {}


def register_pass(pass_id: str, *, needs: Tuple[str, ...]
                  ) -> Callable[[Callable], Callable]:
    """Decorator: add a lint pass under ``pass_id``. ``needs`` declares
    which artifacts the pass consumes — ``--lint-table`` (pure lowering)
    runs only passes whose needs exclude "scenario"."""
    def deco(fn: Callable) -> Callable:
        _REGISTRY[pass_id] = LintPass(pass_id=pass_id,
                                      doc=(fn.__doc__ or "").strip(),
                                      fn=fn, needs=needs)
        return fn
    return deco


def available_passes() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_pass(pass_id: str) -> LintPass:
    try:
        return _REGISTRY[pass_id]
    except KeyError:
        raise KeyError(f"unknown lint pass {pass_id!r}; available: "
                       f"{', '.join(available_passes())}") from None


def run_pass(pass_id: str, spec, art) -> List[Finding]:
    """Run one pass over one executable, applying the spec's
    suppressions. Inapplicable passes (no expectation in the spec)
    return []."""
    p = get_pass(pass_id)
    findings = p.fn(spec, art)
    if pass_id in spec.ignore:
        findings = [dataclasses.replace(f, suppressed=True)
                    for f in findings]
    return findings


# --------------------------------------------------------------------------
# the five shipped passes
# --------------------------------------------------------------------------

def _finding(spec, pass_id, sev, loc, msg, **kw) -> Finding:
    return Finding(pass_id=pass_id, severity=sev, executable=spec.name,
                   location=loc, message=msg, **kw)


@register_pass("no-collectives", needs=("hlo",))
def no_collectives_pass(spec, art) -> List[Finding]:
    """Zero-communication / bytes-equality gate: Gate-Drop LOCAL,
    dropped-chunk, and local-routing executables must compile to ZERO
    all-to-alls (the paper's §3 structural claim); routed executables'
    all-to-all count/bytes must equal the comm/cost.py analytic model
    (the PR-5 telemetry==HLO contract, through the IR walker)."""
    from repro.analysis.hlo import collectives_summary
    exp = spec.expect.get("no-collectives")
    if exp is None:
        return []
    module = art.hlo
    summary = collectives_summary(module)
    a2a = summary.get("all-to-all", {"count": 0, "bytes": 0.0,
                                     "wire_bytes": 0.0})
    out: List[Finding] = []
    if exp.get("zero"):
        if a2a["count"]:
            sites = [f"{i.computation}/%{i.name}" for i in
                     module.find("all-to-all")][:4]
            out.append(_finding(
                spec, "no-collectives", "error", ";".join(sites),
                f"expected ZERO all-to-alls, found {int(a2a['count'])} "
                f"moving {a2a['bytes']:.0f} B"))
        return out
    if exp.get("nonzero") and not a2a["count"]:
        out.append(_finding(
            spec, "no-collectives", "error", module.entry or "entry",
            "expected a routed executable (all-to-alls present), found "
            "none — the expert exchange was silently elided"))
    cost = exp.get("cost")
    if cost is not None:
        if int(a2a["count"]) != int(cost["calls"]):
            out.append(_finding(
                spec, "no-collectives", "error", module.entry or "entry",
                f"all-to-all count {int(a2a['count'])} != cost model "
                f"{int(cost['calls'])}"))
        if float(a2a["bytes"]) != float(cost["bytes"]):
            out.append(_finding(
                spec, "no-collectives", "error", module.entry or "entry",
                f"all-to-all payload {a2a['bytes']:.0f} B != cost model "
                f"{cost['bytes']:.0f} B"))
        if abs(float(a2a["wire_bytes"]) - float(cost["wire_bytes"])) >= 1:
            out.append(_finding(
                spec, "no-collectives", "error", module.entry or "entry",
                f"all-to-all wire {a2a['wire_bytes']:.1f} B != cost model "
                f"{cost['wire_bytes']:.1f} B"))
    return out


@register_pass("dtype-flow", needs=("jaxpr",))
def dtype_flow_pass(spec, art) -> List[Finding]:
    """No f32 leakage in 16-bit paths: flags dot_generals whose operands
    were CONVERTED from bf16/f16 to f32 (2x FLOP/read width vs the
    declared model dtype). Walks the jaxpr, not compiled HLO — XLA:CPU
    legalizes every bf16 dot to convert+f32-dot, which would make the
    violation indistinguishable post-compile. Whitelisted f32
    accumulators (router logits, attention probabilities, f32
    ``preferred_element_type`` over 16-bit operands) don't match: they
    are either below ``min_elems`` or keep 16-bit operands."""
    exp = spec.expect.get("dtype-flow")
    if exp is None:
        return []
    from repro.analysis.jaxprs import f32_upcast_dots
    hits = f32_upcast_dots(art.jaxpr,
                           min_elems=exp.get("min_elems", 4096))
    return [
        _finding(spec, "dtype-flow", "error",
                 "jaxpr:" + ("/".join(h.path) or "top"),
                 f"f32 dot_general over operands widened from "
                 f"{'/'.join(sorted(set(h.src_dtypes)))}; output "
                 f"{h.out_shape} ({h.out_elems} elems) — cast back or "
                 f"use preferred_element_type for f32 accumulation")
        for h in hits]


@register_pass("vmem-budget", needs=("jaxpr",))
def vmem_budget_pass(spec, art) -> List[Finding]:
    """Megakernel VMEM residency: estimates each pallas_call's on-chip
    footprint from its REAL lowered block mappings (grid-varying blocks
    double-buffered, grid-invariant blocks + scratch resident once) and
    fails any launch over the spec's budget (default 16 MiB — TPU v4
    VMEM per core)."""
    exp = spec.expect.get("vmem-budget")
    if exp is None:
        return []
    from repro.analysis.jaxprs import pallas_launches
    budget = exp.get("budget_bytes", 16 << 20)
    out: List[Finding] = []
    for launch in pallas_launches(art.jaxpr):
        used = launch.vmem_bytes()
        if used > budget:
            brk = ", ".join(
                f"{b.name}{list(b.block_shape)}:{b.dtype}"
                f"{'x2' if b.grid_varying else ''}={b.bytes >> 10}KiB"
                for b in launch.buffers)
            out.append(_finding(
                spec, "vmem-budget", "error",
                f"pallas:{launch.kernel_name}",
                f"estimated VMEM {used / 2**20:.2f} MiB > budget "
                f"{budget / 2**20:.2f} MiB (grid {launch.grid}; {brk})"))
    return out


@register_pass("launch-count", needs=("jaxpr",))
def launch_count_pass(spec, art) -> List[Finding]:
    """Kernel-launch budget: pallas_fused must stay a SINGLE pallas_call
    per step (the §11 fusion claim), the unfused pipeline within its
    dispatch/FFN/combine budget. Counted in the jaxpr — a scan body
    counts once, matching per-traced-step launches."""
    exp = spec.expect.get("launch-count")
    if exp is None:
        return []
    from repro.analysis.jaxprs import pallas_launches
    launches = pallas_launches(art.jaxpr)
    budget = exp["max"]
    if len(launches) <= budget:
        return []
    names = ", ".join(l.kernel_name for l in launches)
    return [_finding(
        spec, "launch-count", "error", f"pallas:{names}",
        f"{len(launches)} pallas_call launches > budget {budget}")]


@register_pass("host-sync", needs=("scenario",))
def host_sync_pass(spec, art) -> List[Finding]:
    """No hidden device->host transfers inside steady-state Trainer
    chunks / scheduler ticks (explicit jax.device_get is sanctioned),
    and no jit cache misses across ticks (a growth means a tick
    re-traced — a shape leak re-compiling in the serving loop)."""
    if spec.scenario is None:
        return []
    res = spec.scenario()
    out: List[Finding] = []
    for ev in res.get("events", ()):
        if ev.sanctioned or ev.internal:
            continue
        out.append(_finding(
            spec, "host-sync", "error", ev.origin,
            f"implicit device->host transfer via {ev.method} inside a "
            f"steady-state tick; use jax.device_get if the sync is "
            f"intentional"))
    for label, before, after in res.get("cache_sizes", ()):
        if after > before:
            out.append(_finding(
                spec, "host-sync", "error", f"jit:{label}",
                f"jit cache grew {before} -> {after} across warmed-up "
                f"ticks: a tick re-traced (shape/dtype leak)"))
    return out
