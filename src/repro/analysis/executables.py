"""Named-executable registry for the lint suite (DESIGN.md §12).

Mirrors the PR-1 backend / PR-5 substrate registries: every program the
system ships — the fused train chunk (routed + host_cond dropped), the
sharded MoE layer under all four substrates, decode_pool_step with and
without ``local_routing``, the pallas_fused forward/VJP, the unfused
pallas pipeline, the flash-decode step — registers here as an
ExecutableSpec that can lower itself under the small CPU device mesh,
plus the per-pass EXPECTATIONS the lint passes check it against
(zero a2a vs. cost-model equality, launch budgets, VMEM budgets,
dtype policy, host-sync scenarios).

Builders are lazy: importing this module costs nothing but host math
(the cost-model expectations); devices are touched only when an
executable's artifacts are first requested. Specs needing the mesh
declare ``n_devices=8`` and are skipped (with a warning finding) when
fewer devices are visible.

Suppressions: pass ``ignore=(...)`` or write a trailing
``# lint: ignore[pass-id]`` comment on the ``register_executable``
call line — the registrar reads it from source.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import re
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["Artifacts", "ExecutableSpec", "available_executables",
           "get_executable", "register_executable"]

_IGNORE_COMMENT = re.compile(r"#\s*lint:\s*ignore\[([\w\-,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class ExecutableSpec:
    name: str
    build: Callable[[], Tuple[Callable, tuple]]   # -> (fn, example_args)
    expect: Dict[str, Dict[str, Any]]
    ignore: Tuple[str, ...] = ()
    scenario: Optional[Callable[[], Dict[str, Any]]] = None
    n_devices: int = 1                            # devices the build needs


class Artifacts:
    """Lazy per-executable artifacts: the jaxpr (pre-lowering truth for
    dtypes/launches) and the parsed compiled-HLO module (truth for
    collectives). Each is built once and cached."""

    def __init__(self, spec: ExecutableSpec):
        self._spec = spec
        self._built: Optional[Tuple[Callable, tuple]] = None

    def _fn_args(self):
        if self._built is None:
            self._built = self._spec.build()
        return self._built

    @functools.cached_property
    def jaxpr(self):
        import jax
        fn, args = self._fn_args()
        return jax.make_jaxpr(fn)(*args)

    @functools.cached_property
    def hlo(self):
        import jax
        from repro.analysis.hlo import parse_hlo
        fn, args = self._fn_args()
        text = jax.jit(fn).lower(*args).compile().as_text()
        return parse_hlo(text)


_REGISTRY: Dict[str, ExecutableSpec] = {}


def register_executable(spec: ExecutableSpec) -> ExecutableSpec:
    """Register a spec; merges ``# lint: ignore[pass-id, ...]`` comments
    written anywhere on the (possibly multi-line) registration call into
    ``spec.ignore`` — scans the caller's source from the call line until
    its parentheses close."""
    frame = inspect.stack()[1]
    extra = []
    try:
        lines, _ = inspect.findsource(frame.frame)
        depth = 0
        for ln in lines[frame.lineno - 1:frame.lineno + 31]:
            m = _IGNORE_COMMENT.search(ln)
            if m:
                extra += [p.strip() for p in m.group(1).split(",")
                          if p.strip()]
            depth += ln.count("(") - ln.count(")")
            if depth <= 0:
                break
    except (OSError, TypeError):          # exec'd / REPL code: no source
        pass
    if extra:
        spec = dataclasses.replace(spec, ignore=spec.ignore + tuple(extra))
    _REGISTRY[spec.name] = spec
    return spec


def available_executables() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_executable(name: str) -> ExecutableSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown executable {name!r}; available: "
                       f"{', '.join(available_executables())}") from None


# --------------------------------------------------------------------------
# shared config builders (host math only)
# --------------------------------------------------------------------------

def _moe_cfg(substrate: str = "dense", *, backend: str = "sharded",
             dtype: str = "float32", top_k: int = 2, gated: bool = True,
             d_model: int = 32, d_ff: int = 64, n_experts: int = 8,
             n_chunks: int = 4):
    from repro.configs.base import (CommConfig, GatingDropoutConfig,
                                    ModelConfig, MoEConfig)
    return ModelConfig(
        d_model=d_model, d_ff=d_ff, vocab=64, dtype=dtype,
        gated_mlp=gated,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=d_ff,
                      jitter_eps=0.0,
                      comm=CommConfig(substrate=substrate,
                                      n_chunks=n_chunks),
                      backend=backend,
                      gating_dropout=GatingDropoutConfig(
                          mode="gate_drop", rate=0.3)))


def _train_cfg(substrate: str = "hierarchical_compressed", *,
               n_chunks: int = 4):
    from repro.configs.base import (CommConfig, GatingDropoutConfig,
                                    ModelConfig, MoEConfig)
    # scan_layers=False: HLO counts a scanned segment body ONCE; the cost
    # model prices per MoE layer — unrolled, the two agree exactly.
    # (Overlapped substrates are already HLO-exact under scan: the chunk
    # pipeline is an unrolled Python loop, DESIGN.md §14.)
    return ModelConfig(
        d_model=32, d_ff=64, vocab=64, n_layers=2, n_heads=2, n_kv_heads=2,
        remat=False, scan_layers=False, dtype="float32",
        param_dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=64, jitter_eps=0.0,
                      comm=CommConfig(substrate=substrate,
                                      n_chunks=n_chunks),
                      backend="sharded",
                      gating_dropout=GatingDropoutConfig(
                          mode="gate_drop", rate=0.3,
                          strategy="host_cond")))


def _decode_cfg():
    from repro.configs.base import (GatingDropoutConfig, ModelConfig,
                                    MoEConfig)
    return ModelConfig(
        d_model=64, d_ff=128, vocab=100, n_layers=1, n_heads=2,
        n_kv_heads=2, remat=False, scan_layers=False, dtype="float32",
        param_dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                      backend="sharded",
                      gating_dropout=GatingDropoutConfig(
                          mode="gate_drop", rate=0.3)))


def _layer_cost_expect(cfg, *, tokens_per_shard: int, ep: int):
    from repro.comm import layer_cost
    c = layer_cost(cfg, tokens_per_shard=tokens_per_shard, ep=ep)
    return {"cost": {"calls": c["calls"], "bytes": c["bytes"],
                     "wire_bytes": c["wire_bytes"]}}


def _step_cost_expect(cfg, *, tokens_per_shard: int, ep: int):
    from repro.comm.cost import step_cost
    c = step_cost(cfg, tokens_per_shard=tokens_per_shard, ep=ep,
                  backward=True)
    return {"cost": {"calls": c["calls"], "bytes": c["bytes"],
                     "wire_bytes": c["wire_bytes"]}}


# --------------------------------------------------------------------------
# builders (device-touching, lazy)
# --------------------------------------------------------------------------

def _build_moe_layer(substrate: str, decision: bool):
    def build():
        import jax
        from repro.core import init_moe_params, moe_sharded, ParallelContext
        from repro.launch.mesh import make_mesh
        cfg = _moe_cfg(substrate)
        ctx = ParallelContext(mesh=make_mesh((8,), ("data",)))
        p = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))

        def fn(p_, x_):
            return moe_sharded(p_, x_, cfg, ctx, rng=None,
                               decision=decision)
        return fn, (p, x)
    return build


def _build_train_chunk(decision: bool,
                       substrate: str = "hierarchical_compressed",
                       frame: bool = True):
    def build():
        import jax
        import jax.numpy as jnp
        from repro.configs.base import TrainConfig
        from repro.core.moe import ParallelContext
        from repro.launch.mesh import make_mesh
        from repro.models import init_model
        from repro.training.loop import make_chunk_step
        from repro.training.steps import init_train_state
        cfg = _train_cfg(substrate)
        tc = TrainConfig(lr=1e-3, warmup_steps=4, seed=0,
                         metrics_frame=frame)
        ctx = ParallelContext(mesh=make_mesh((8,), ("data",)))
        state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
        K, B, L = 2, 8, 16
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (K, B, L), 3, cfg.vocab)
        batches = {"tokens": toks,
                   "labels": jnp.roll(toks, -1, axis=2),
                   "loss_mask": jnp.ones((K, B, L), jnp.float32)}
        chunk = make_chunk_step(cfg, tc, ctx, jit=False)

        def fn(state_, batches_):
            return chunk(state_, batches_, decision)
        return fn, (state, batches)
    return build


def _build_decode_pool(local_routing: bool):
    def build():
        import jax
        import jax.numpy as jnp
        from repro.core.moe import ParallelContext
        from repro.launch.mesh import make_mesh
        from repro.models import init_model
        from repro.serve.engine import decode_pool_step, init_slot_pool
        cfg = _decode_cfg()
        ctx = ParallelContext(mesh=make_mesh((8,), ("data",)))
        params = init_model(jax.random.PRNGKey(0), cfg)
        S = 8
        pool = init_slot_pool(cfg, S, 32)
        tok = jnp.zeros((S,), jnp.int32)
        pos = jnp.full((S,), 4, jnp.int32)
        alive = jnp.ones((S,), bool)

        def fn(p_, c_, t_, i_, a_):
            return decode_pool_step(p_, c_, t_, i_, a_, cfg, ctx,
                                    local_routing=local_routing)
        return fn, (params, pool, tok, pos, alive)
    return build


def _build_decode_paged(local_routing: bool):
    def build():
        import jax
        import jax.numpy as jnp
        from repro.core.moe import ParallelContext
        from repro.launch.mesh import make_mesh
        from repro.models import init_model
        from repro.serve.paged import (PagedLayout, decode_paged_step,
                                       paged_pool_like)
        cfg = _decode_cfg()
        ctx = ParallelContext(mesh=make_mesh((8,), ("data",)))
        params = init_model(jax.random.PRNGKey(0), cfg)
        S, max_seq = 8, 32
        layout = PagedLayout(page_size=8, n_pages=24, seq_len=max_seq)
        batch = {"tokens": jnp.zeros((S, 4), jnp.int32)}
        pool = paged_pool_like(params, batch, cfg, ctx, max_seq=max_seq,
                               n_slots=S, layout=layout)
        tables = jnp.tile(jnp.arange(layout.n_blocks, dtype=jnp.int32),
                          (S, 1))
        tok = jnp.zeros((S,), jnp.int32)
        pos = jnp.full((S,), 4, jnp.int32)
        alive = jnp.ones((S,), bool)

        def fn(p_, c_, bt_, t_, i_, a_):
            return decode_paged_step(p_, c_, bt_, t_, i_, a_, cfg, ctx,
                                     local_routing=local_routing)
        return fn, (params, pool, tables, tok, pos, alive)
    return build


def _build_pallas_fused(mode: str):
    def build():
        import jax
        import jax.numpy as jnp
        from repro.core import init_moe_params
        from repro.core.backend import get_backend
        cfg = _moe_cfg(backend="pallas_fused")
        p = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        backend = get_backend("pallas_fused")

        def fwd(p_, x_):
            y, _aux = backend(p_, x_, cfg, None, rng=None, decision=False,
                              is_training=True, interpret=True)
            return y

        if mode == "fwd":
            return fwd, (p, x)

        def vjp(p_, x_):
            return jax.grad(lambda pp, xx: jnp.sum(fwd(pp, xx) ** 2),
                            argnums=(0, 1))(p_, x_)
        return vjp, (p, x)
    return build


def _build_pallas_pipeline():
    def build():
        import jax
        from repro.core import init_moe_params
        from repro.core.backend import get_backend
        # ungated expert MLP: dispatch + 2 grouped matmuls + combine = 4
        # launches (the gate matmul would make it 5)
        cfg = _moe_cfg(backend="pallas", gated=False)
        p = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        backend = get_backend("pallas")

        def fn(p_, x_):
            y, _aux = backend(p_, x_, cfg, None, rng=None, decision=False,
                              is_training=True, interpret=True)
            return y
        return fn, (p, x)
    return build


def _build_flash_decode():
    def build():
        import jax
        import jax.numpy as jnp
        from repro.kernels.flash_decode import flash_decode
        key = jax.random.PRNGKey(0)
        B, H, KV, hd, S = 8, 4, 2, 16, 64
        q = jax.random.normal(key, (B, H, hd))
        k = jax.random.normal(key, (B, S, KV, hd))
        v = jax.random.normal(key, (B, S, KV, hd))
        idx = jnp.full((B,), 17, jnp.int32)

        def fn(q_, k_, v_, i_):
            return flash_decode(q_, k_, v_, i_, interpret=True)
        return fn, (q, k, v, idx)
    return build


def _build_flash_decode_paged():
    def build():
        import jax
        import jax.numpy as jnp
        from repro.kernels.flash_decode import flash_decode_paged
        key = jax.random.PRNGKey(0)
        B, H, KV, hd, ps, npg, nb = 8, 4, 2, 16, 16, 24, 4
        q = jax.random.normal(key, (B, H, hd))
        k = jax.random.normal(key, (npg + 1, ps, KV, hd))
        v = jax.random.normal(key, (npg + 1, ps, KV, hd))
        bt = jnp.tile(jnp.arange(nb, dtype=jnp.int32), (B, 1))
        idx = jnp.full((B,), 17, jnp.int32)

        def fn(q_, k_, v_, bt_, i_):
            return flash_decode_paged(q_, k_, v_, bt_, i_, interpret=True)
        return fn, (q, k, v, bt, idx)
    return build


def _build_bf16_loss():
    def build():
        import jax
        import jax.numpy as jnp
        import dataclasses as dc
        from repro.models import init_model
        from repro.training.steps import total_loss
        cfg = dc.replace(_moe_cfg(backend="oracle"), dtype="bfloat16",
                         param_dtype="bfloat16", n_layers=2, n_heads=2,
                         n_kv_heads=2, remat=False)
        params = init_model(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (2, 16), 3, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
                 "loss_mask": jnp.ones((2, 16), jnp.float32)}

        def fn(p_, b_):
            return total_loss(p_, b_, cfg, None, rng=None, decision=False)
        return fn, (params, batch)
    return build


# --------------------------------------------------------------------------
# host-sync scenarios (execute steady-state ticks under the guard)
# --------------------------------------------------------------------------

def _trainer_scenario():
    import jax
    from repro.analysis.hostsync import guard_host_transfers, jit_cache_sizes
    from repro.configs.base import TrainConfig
    from repro.data import LMTaskConfig, SyntheticLM, stack_batches
    from repro.obs.trace import Tracer
    from repro.training.loop import Trainer
    import dataclasses as dc
    cfg = dc.replace(_moe_cfg(backend="oracle"), n_layers=1, n_heads=2,
                     n_kv_heads=2, remat=False)
    # metrics_frame stays ON and the tracer is ENABLED: the guard must
    # stay green with the full observability layer live (DESIGN.md §15)
    tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=0, steps=8)
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
    trainer = Trainer(cfg, tc, lambda i: task.sample_batch(i, 2),
                      chunk=2, strategy="traced_cond", prefetch=False,
                      log=None, tracer=Tracer(enabled=True))
    fetch = lambda lo, hi: stack_batches(trainer.batch_fn, lo, hi)
    trainer._dispatch((0, 2), fetch(0, 2))       # warmup: compile outside
    evs = []
    with guard_host_transfers(events=evs):
        before = jit_cache_sizes([trainer.chunk_fn])
        trainer._dispatch((2, 4), fetch(2, 4))
        trainer._dispatch((4, 6), fetch(4, 6))
        after = jit_cache_sizes([trainer.chunk_fn])
    return {"events": evs,
            "cache_sizes": [("chunk_fn", before[0], after[0])]}


def _scheduler_scenario():
    import numpy as np
    from repro.analysis.hostsync import guard_host_transfers, jit_cache_sizes
    from repro.serve.engine import GenerateConfig
    from repro.serve.scheduler import ContinuousScheduler, Request
    from repro.models import init_model
    import jax
    import dataclasses as dc
    cfg = dc.replace(_moe_cfg(backend="oracle"), n_layers=1, n_heads=2,
                     n_kv_heads=2, remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new=24, eos_id=-1)
    from repro.obs import MetricsRegistry, Tracer
    # tracer + registry live: span records and histogram observes are
    # pure host work, so the guarded ticks must stay one-sync
    sched = ContinuousScheduler(params, cfg, gen, n_slots=4,
                                prefill_buckets=(8,),
                                registry=MetricsRegistry(),
                                tracer=Tracer(enabled=True))
    for rid in range(3):
        sched.submit(Request(rid=rid,
                             tokens=np.arange(3 + rid, dtype=np.int32) + 3))
    sched.step(0.0)                              # warmup: prefill + decode
    sched.step(0.0)                              # warmup: steady decode
    jits = [sched._decode_fn, sched._prefill]
    evs = []
    with guard_host_transfers(events=evs):
        before = jit_cache_sizes(jits)
        for _ in range(3):                       # steady-state ticks
            sched.step(0.0)
        after = jit_cache_sizes(jits)
    return {"events": evs,
            "cache_sizes": [("pool_decode", before[0], after[0]),
                            ("bucket_prefill", before[1], after[1])]}


def _paged_scheduler_scenario():
    import numpy as np
    from repro.analysis.hostsync import guard_host_transfers, jit_cache_sizes
    from repro.configs.base import PagedKVConfig
    from repro.serve.engine import GenerateConfig
    from repro.serve.scheduler import PagedScheduler, Request
    from repro.models import init_model
    import jax
    import dataclasses as dc
    cfg = dc.replace(_moe_cfg(backend="oracle"), n_layers=1, n_heads=2,
                     n_kv_heads=2, remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new=24, eos_id=-1)
    from repro.obs import MetricsRegistry, Tracer
    # ample pages: the steady-state tick must stay on the one-sync path
    # (preemption swap-out is the documented exceptional second sync);
    # tracer + registry live, same as the base-scheduler scenario
    sched = PagedScheduler(params, cfg, gen, n_slots=4,
                           prefill_buckets=(8,),
                           paged=PagedKVConfig(page_size=8,
                                               n_slots_equiv=8),
                           registry=MetricsRegistry(),
                           tracer=Tracer(enabled=True))
    for rid in range(3):
        sched.submit(Request(rid=rid,
                             tokens=np.arange(3 + rid, dtype=np.int32) + 3))
    sched.step(0.0)                              # warmup: prefill + decode
    sched.step(0.0)                              # warmup: steady decode
    jits = [sched._decode_fn, sched._prefill]
    evs = []
    with guard_host_transfers(events=evs):
        before = jit_cache_sizes(jits)
        for _ in range(3):                       # steady-state ticks
            sched.step(0.0)
        after = jit_cache_sizes(jits)
    return {"events": evs,
            "cache_sizes": [("paged_decode", before[0], after[0]),
                            ("paged_prefill", before[1], after[1])]}


# --------------------------------------------------------------------------
# the registry
# --------------------------------------------------------------------------

_VMEM = {"budget_bytes": 16 << 20}
_DTYPE = {"min_elems": 4096}

# all eight substrates (DESIGN.md §10, §14): the overlapped rows assert
# the §14 invariant in the lint gate — a2a call count = n_eff x the base
# substrate's at EXACTLY equal total bytes/wire (the chunk pipeline is
# an unrolled loop, so HLO carries each per-chunk collective distinctly)
from repro.configs.base import COMM_SUBSTRATES as _ALL_SUBS  # noqa: E402

for _sub in _ALL_SUBS:
    register_executable(ExecutableSpec(
        name=f"moe_layer/{_sub}",
        build=_build_moe_layer(_sub, decision=False),
        expect={"no-collectives": _layer_cost_expect(
            _moe_cfg(_sub), tokens_per_shard=16, ep=8)},
        n_devices=8))

register_executable(ExecutableSpec(
    name="moe_layer/local",
    build=_build_moe_layer("dense", decision=True),
    expect={"no-collectives": {"zero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="train_chunk/routed",
    build=_build_train_chunk(decision=False),
    expect={"no-collectives": _step_cost_expect(
        _train_cfg(), tokens_per_shard=16, ep=8)},
    n_devices=8))

register_executable(ExecutableSpec(
    name="train_chunk/dropped",
    build=_build_train_chunk(decision=True),
    expect={"no-collectives": {"zero": True}},
    n_devices=8))

# MetricsFrame non-interference (DESIGN.md §15): switching the in-graph
# telemetry frame OFF must leave the compiled chunk's collectives exactly
# at the cost model — the frame only widens the fetched metric dict, it
# never adds (or removes) communication
register_executable(ExecutableSpec(
    name="train_chunk/frame_off",
    build=_build_train_chunk(decision=False, frame=False),
    expect={"no-collectives": _step_cost_expect(
        _train_cfg(), tokens_per_shard=16, ep=8)},
    n_devices=8))

register_executable(ExecutableSpec(
    name="train_chunk/overlapped",
    build=_build_train_chunk(decision=False, substrate="overlapped"),
    expect={"no-collectives": _step_cost_expect(
        _train_cfg("overlapped"), tokens_per_shard=16, ep=8)},
    n_devices=8))

register_executable(ExecutableSpec(
    name="train_chunk/overlapped_dropped",
    build=_build_train_chunk(decision=True, substrate="overlapped"),
    expect={"no-collectives": {"zero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="decode_pool/routed",
    build=_build_decode_pool(local_routing=False),
    expect={"no-collectives": {"nonzero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="decode_pool/local",
    build=_build_decode_pool(local_routing=True),
    expect={"no-collectives": {"zero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="decode_paged/routed",
    build=_build_decode_paged(local_routing=False),
    expect={"no-collectives": {"nonzero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="decode_paged/local",
    build=_build_decode_paged(local_routing=True),
    expect={"no-collectives": {"zero": True}},
    n_devices=8))

register_executable(ExecutableSpec(
    name="pallas_fused/fwd",
    build=_build_pallas_fused("fwd"),
    expect={"launch-count": {"max": 1}, "vmem-budget": _VMEM,
            "dtype-flow": _DTYPE, "no-collectives": {"zero": True}}))

register_executable(ExecutableSpec(
    name="pallas_fused/vjp",
    build=_build_pallas_fused("vjp"),
    expect={"launch-count": {"max": 1}, "vmem-budget": _VMEM}))

register_executable(ExecutableSpec(
    name="pallas_pipeline/fwd",
    build=_build_pallas_pipeline(),
    expect={"launch-count": {"max": 4}, "vmem-budget": _VMEM,
            "no-collectives": {"zero": True}}))

register_executable(ExecutableSpec(
    name="flash_decode/step",
    build=_build_flash_decode(),
    expect={"launch-count": {"max": 1}, "vmem-budget": _VMEM,
            "dtype-flow": _DTYPE}))

register_executable(ExecutableSpec(
    name="flash_decode/paged",
    build=_build_flash_decode_paged(),
    expect={"launch-count": {"max": 1}, "vmem-budget": _VMEM,
            "dtype-flow": _DTYPE}))

register_executable(ExecutableSpec(
    name="model_loss/bf16",
    build=_build_bf16_loss(),
    expect={"dtype-flow": _DTYPE, "no-collectives": {"zero": True}}))

register_executable(ExecutableSpec(
    name="trainer/ticks",
    build=lambda: (_ for _ in ()).throw(
        RuntimeError("trainer/ticks is scenario-only")),
    expect={"host-sync": {}},
    scenario=_trainer_scenario))

register_executable(ExecutableSpec(
    name="scheduler/ticks",
    build=lambda: (_ for _ in ()).throw(
        RuntimeError("scheduler/ticks is scenario-only")),
    expect={"host-sync": {}},
    scenario=_scheduler_scenario))

register_executable(ExecutableSpec(
    name="paged_scheduler/ticks",
    build=lambda: (_ for _ in ()).throw(
        RuntimeError("paged_scheduler/ticks is scenario-only")),
    expect={"host-sync": {}},
    scenario=_paged_scheduler_scenario))
