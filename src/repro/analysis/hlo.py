"""HLO-text IR walker (DESIGN.md §12).

Parses the ``compiled.as_text()`` dump into a small typed IR —
computations, instructions, result shapes (tuple results included),
operands, attributes (``replica_groups`` in both list and iota form,
``channel_id``, ``calls=``) — that the lint passes and the roofline
walk instead of ad-hoc regexes. Subsumes the seed-era
``launch/hlo_analysis.py::parse_collectives`` (same output schema,
kept as a function here so every caller migrated without changing
its numbers) and fixes its two latent bugs:

  * unknown payload dtypes silently priced at 4 bytes — ``shape_bytes``
    now raises, and the table covers the int8/fp8/pred wire dtypes the
    compressed substrate actually moves;
  * ``get-tuple-element`` lines were excluded only because layout braces
    ``{2,1,0}`` happened to break the old shape regex — the walker
    matches opcodes structurally, so textual noise like an operand named
    ``%all-to-all.1`` can never be miscounted as a collective.

Import-safe: never touches jax device state.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["DTYPE_BYTES", "HloComputation", "HloInstr", "HloModule",
           "HloShape", "UnknownDtypeError", "collectives_summary",
           "parse_collectives", "parse_hlo", "shape_bytes"]

# wire width of every dtype XLA prints in shapes. THE dtype table of the
# repo: comm/cost.py prices quantized substrate payloads off it too.
DTYPE_BYTES: Dict[str, int] = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}


class UnknownDtypeError(KeyError):
    """A shape used a dtype missing from DTYPE_BYTES — extend the table
    instead of silently pricing it wrong (the seed parser defaulted to 4
    bytes, which under-priced f64/c128 and over-priced every 8-bit wire
    dtype by 4x)."""


def shape_bytes(dtype: str, dims) -> int:
    """Bytes of an array shape. ``dims`` is an int iterable or the
    comma-joined string XLA prints. Raises UnknownDtypeError on a dtype
    missing from DTYPE_BYTES."""
    if dtype not in DTYPE_BYTES:
        raise UnknownDtypeError(
            f"dtype {dtype!r} not in analysis.hlo.DTYPE_BYTES")
    n = 1
    if isinstance(dims, str):
        dims = [int(d) for d in dims.split(",") if d.strip()]
    for d in dims:
        n *= int(d)
    return n * DTYPE_BYTES[dtype]


@dataclasses.dataclass(frozen=True)
class HloShape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def bytes(self) -> int:
        return shape_bytes(self.dtype, self.dims)


@dataclasses.dataclass(frozen=True)
class HloInstr:
    name: str                     # %-less instruction name
    opcode: str                   # normalized: "-start" folded, no "-done"
    shapes: Tuple[HloShape, ...]  # result shapes (>=1; tuples flattened)
    operands: Tuple[str, ...]     # %-less operand instruction names
    attrs: Dict[str, str]         # raw attr text by key (channel_id, ...)
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]]
    channel_id: Optional[int]
    called: Tuple[str, ...]       # computations from calls={...}/to_apply=
    computation: str              # owning computation name
    is_root: bool
    raw: str                      # the source line (stripped)

    @property
    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def group_size(self) -> int:
        if not self.replica_groups:
            return 1
        return max(len(g) for g in self.replica_groups)


@dataclasses.dataclass
class HloComputation:
    name: str
    instrs: List[HloInstr]
    is_entry: bool


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, HloComputation]
    entry: Optional[str]

    def instructions(self) -> Iterator[HloInstr]:
        for comp in self.computations.values():
            yield from comp.instrs

    def find(self, opcode: str) -> List[HloInstr]:
        return [i for i in self.instructions() if i.opcode == opcode]

    def called_by(self, instr: HloInstr) -> List[HloComputation]:
        """Fusion/call/custom-call bodies of an instruction."""
        return [self.computations[c] for c in instr.called
                if c in self.computations]


# --------------------------------------------------------------------------
# parsing
# --------------------------------------------------------------------------

# computation header: `ENTRY %main.42 (...) -> ... {` / `%fused (...) {`
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
# instruction: `  [ROOT ]%name = <rhs>`
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# one shape token: dtype[dims]{layout}? — layout/tiling braces skipped
_SHAPE_TOK = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]"
                        r"(?:\{[^}]*\})?")
# opcode after the result shape(s): letters and dashes, then `(`
_OPCODE_RE = re.compile(r"\s*([a-z][a-z0-9\-]*)\s*\(")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_GROUPS_LIST = re.compile(r"replica_groups=(\{\{[0-9,\{\}\s]*\}\})")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLS_RE = re.compile(r"(?:calls|to_apply|fused_computation)="
                       r"%?([\w\.\-]+)")
_ATTR_RE = re.compile(r"([a-z_]+)=")

COLLECTIVE_OPS = ("all-to-all", "all-gather", "all-reduce",
                  "reduce-scatter", "collective-permute")


def _parse_result_shapes(rhs: str) -> Tuple[Tuple[HloShape, ...], int]:
    """Leading shape spec of an instruction rhs -> (shapes, end offset).
    Handles single shapes and tuple results `(f32[..]{..}, u8[..])`."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        span = rhs[1:i]
        shapes = tuple(HloShape(d, tuple(int(x) for x in dims.split(",")
                                         if x.strip()))
                       for d, dims in _SHAPE_TOK.findall(span))
        return shapes, i + 1
    m = _SHAPE_TOK.match(rhs)
    if not m:
        return (), 0
    dims = tuple(int(x) for x in m.group(2).split(",") if x.strip())
    return (HloShape(m.group(1), dims),), m.end()


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        depth += s[i] == "("
        depth -= s[i] == ")"
        if depth == 0:
            return i
    return len(s) - 1


def _normalize_opcode(op: str) -> Optional[str]:
    """Fold async `-start` into the base op; drop `-done`/`-update`
    halves so async pairs count once."""
    if op.endswith("-done") or op.endswith("-update"):
        return None
    if op.endswith("-start"):
        return op[:-len("-start")]
    return op


def _parse_instr(line: str, comp: str) -> Optional[HloInstr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
    shapes, off = _parse_result_shapes(rhs)
    if not shapes:
        return None
    rest = rhs[off:]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    raw_op = om.group(1)
    open_paren = om.end() - 1
    close = _matching_paren(rest, open_paren)
    operand_span = rest[open_paren + 1:close]
    tail = rest[close + 1:]
    opcode = _normalize_opcode(raw_op)
    if opcode is None:
        return None
    operands = tuple(_OPERAND_NAME.findall(
        _SHAPE_TOK.sub("", operand_span)))
    attrs = {}
    for am in _ATTR_RE.finditer(tail):
        attrs[am.group(1)] = ""          # presence map; values below
    cm = _CHANNEL_RE.search(tail)
    channel_id = int(cm.group(1)) if cm else None
    groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    gl = _GROUPS_LIST.search(tail)
    if gl:
        groups = tuple(tuple(int(x) for x in g.split(",") if x.strip())
                       for g in re.findall(r"\{([0-9,\s]+)\}", gl.group(1)))
    else:
        gi = _GROUPS_IOTA.search(tail)
        if gi:
            n_groups, size = int(gi.group(1)), int(gi.group(2))
            groups = tuple(tuple(range(g * size, (g + 1) * size))
                           for g in range(n_groups))
    called = tuple(_CALLS_RE.findall(tail))
    return HloInstr(name=name, opcode=opcode, shapes=shapes,
                    operands=operands, attrs=attrs, replica_groups=groups,
                    channel_id=channel_id, called=called, computation=comp,
                    is_root=is_root, raw=line.strip())


def parse_hlo(text: str) -> HloModule:
    """Parse a compiled-HLO text dump into an HloModule."""
    comps: Dict[str, HloComputation] = {}
    cur: Optional[HloComputation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        cm = _COMP_RE.match(stripped)
        if cm and "=" not in stripped.split("(", 1)[0]:
            cur = HloComputation(name=cm.group(2), instrs=[],
                                 is_entry=bool(cm.group(1)))
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and "=" in stripped:
            instr = _parse_instr(line, cur.name)
            if instr is not None:
                cur.instrs.append(instr)
    entry = next((n for n, c in comps.items() if c.is_entry), None)
    return HloModule(computations=comps, entry=entry)


# --------------------------------------------------------------------------
# collective accounting (the PR-5 telemetry==HLO contract)
# --------------------------------------------------------------------------

def _wire_bytes(op: str, payload: float, g: int) -> float:
    """Per-device ring-model wire traffic of one collective op."""
    if op == "all-gather":
        return payload * (g - 1) / max(g, 1)
    if op == "all-reduce":
        return 2 * payload * (g - 1) / max(g, 1)
    if op == "reduce-scatter":
        return payload * (g - 1)          # result is the scattered shard
    if op == "all-to-all":
        return payload * (g - 1) / max(g, 1)
    return float(payload)                  # collective-permute


def collectives_summary(module: HloModule) -> Dict[str, Dict[str, float]]:
    """Per-kind collective counts/bytes over a parsed module — the
    numbers comm/cost.py and the substrate telemetry are pinned to.
    ``bytes`` sums per-device RESULT bytes (tuple results summed),
    ``wire_bytes`` applies the ring model per op."""
    out: Dict[str, Dict[str, float]] = {}
    for instr in module.instructions():
        if instr.opcode not in COLLECTIVE_OPS:
            continue
        payload = instr.result_bytes
        g = instr.group_size
        rec = out.setdefault(instr.opcode, {"count": 0, "bytes": 0.0,
                                            "wire_bytes": 0.0,
                                            "max_group": 1})
        rec["count"] += 1
        rec["bytes"] += payload
        rec["max_group"] = max(rec["max_group"], g)
        rec["wire_bytes"] += _wire_bytes(instr.opcode, payload, g)
    return out


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Back-compat entry (old launch/hlo_analysis.py signature): HLO text
    -> per-kind collective summary, now through the IR walker."""
    return collectives_summary(parse_hlo(hlo))
