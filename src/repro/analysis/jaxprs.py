"""Jaxpr walker (DESIGN.md §12): the pre-lowering half of the analyzer.

Compiled HLO is the truth for collectives, but XLA:CPU rewrites every
bf16 matmul into convert->f32-dot — at the compiled level a deliberate
f32 upcast and a legitimate bf16 dot are indistinguishable (and CSE can
merge them). The jaxpr preserves the dtypes the program was WRITTEN
with, so the dtype-flow pass and the pallas launch/VMEM accounting walk
it instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax

__all__ = ["PallasLaunch", "count_primitive", "f32_upcast_dots",
           "pallas_launches", "walk_eqns"]


def _sub_jaxprs(eqn) -> Iterator[Any]:
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for u in vs:
            if hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                yield u.jaxpr               # ClosedJaxpr
            elif hasattr(u, "eqns"):
                yield u                      # raw Jaxpr


def walk_eqns(jaxpr, path: Tuple[str, ...] = ()
              ) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Yield (eqn, path) over a jaxpr and every nested sub-jaxpr
    (pjit/scan/while/cond bodies, custom_vjp calls, ...). ``path`` is the
    chain of enclosing primitive names — the structured location the
    findings carry."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)   # accept ClosedJaxpr
    for eqn in jaxpr.eqns:
        yield eqn, path
        for sub in _sub_jaxprs(eqn):
            yield from walk_eqns(sub, path + (eqn.primitive.name,))


def count_primitive(jaxpr, name: str) -> int:
    """Number of eqns binding ``name`` anywhere in the jaxpr. NOTE a
    scan/while body counts ONCE (static launch count per traced step),
    which is exactly the invariant the launch-count pass gates."""
    return sum(1 for eqn, _ in walk_eqns(jaxpr)
               if eqn.primitive.name == name)


# --------------------------------------------------------------------------
# dtype flow
# --------------------------------------------------------------------------

_F16 = ("bfloat16", "float16")


def _def_map(jaxpr) -> Dict[Any, Any]:
    """var -> defining eqn, across every nesting level (jax Vars are
    unique objects, so one flat map is sound)."""
    defs: Dict[Any, Any] = {}
    for eqn, _ in walk_eqns(jaxpr):
        for v in eqn.outvars:
            defs[v] = eqn
    return defs


@dataclasses.dataclass(frozen=True)
class UpcastDot:
    path: Tuple[str, ...]
    out_shape: Tuple[int, ...]
    out_elems: int
    src_dtypes: Tuple[str, ...]   # 16-bit dtypes the operands came from


def f32_upcast_dots(jaxpr, *, min_elems: int = 4096) -> List[UpcastDot]:
    """Find dot_general eqns computing in f32 over operands that were
    CONVERTED from a 16-bit dtype — the "unexpected upcast" shape: the
    matmul's FLOPs and its operand reads run at 2x the width the model
    declared. Whitelisted f32 accumulators (router logits, attention
    probabilities, ``preferred_element_type=f32`` over 16-bit inputs)
    stay legal: small outputs (< min_elems) are skipped, and a dot whose
    operands are STILL 16-bit never matches regardless of its
    accumulation dtype."""
    defs = _def_map(jaxpr)
    hits: List[UpcastDot] = []
    for eqn, path in walk_eqns(jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        out = eqn.outvars[0].aval
        if str(out.dtype) != "float32":
            continue
        elems = 1
        for d in out.shape:
            elems *= int(d)
        if elems < min_elems:
            continue
        srcs = []
        for v in eqn.invars:
            if str(getattr(v.aval, "dtype", "")) != "float32":
                srcs = []
                break
            src = defs.get(v)
            if (src is not None
                    and src.primitive.name == "convert_element_type"
                    and str(src.invars[0].aval.dtype) in _F16):
                srcs.append(str(src.invars[0].aval.dtype))
        if srcs:   # at least one operand is a widened 16-bit tensor
            hits.append(UpcastDot(path=path, out_shape=tuple(out.shape),
                                  out_elems=elems, src_dtypes=tuple(srcs)))
    return hits


# --------------------------------------------------------------------------
# pallas launches + block footprints
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockBuffer:
    name: str                 # in0 / in1 / ... / out0 / scratch0
    block_shape: Tuple[int, ...]
    dtype: str
    bytes: int                # ONE buffer copy
    grid_varying: bool        # block smaller than the array -> pipelined


@dataclasses.dataclass(frozen=True)
class PallasLaunch:
    kernel_name: str
    path: Tuple[str, ...]
    grid: Tuple[int, ...]
    buffers: Tuple[BlockBuffer, ...]

    def vmem_bytes(self, *, double_buffer: bool = True) -> int:
        """Estimated VMEM residency: grid-varying blocks are double-
        buffered by the pipeline (x2), grid-invariant blocks and scratch
        stay resident once."""
        total = 0
        for b in self.buffers:
            mult = 2 if (double_buffer and b.grid_varying) else 1
            total += mult * b.bytes
        return total


def _np_bytes(shape, dtype) -> int:
    import numpy as np
    n = 1
    for d in shape:
        n *= int(d)
    return n * np.dtype(dtype).itemsize


def pallas_launches(jaxpr) -> List[PallasLaunch]:
    """Extract every pallas_call in a jaxpr with its grid and per-operand
    block footprint, read from the REAL lowered grid_mapping (not a
    re-derivation of the block-spec math)."""
    out: List[PallasLaunch] = []
    for eqn, path in walk_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        name_info = eqn.params.get("name_and_src_info")
        kname = getattr(name_info, "name", None) or str(name_info or "pallas")
        buffers: List[BlockBuffer] = []
        n_in = len(eqn.invars)
        for i, bm in enumerate(gm.block_mappings):
            sd = bm.array_shape_dtype
            block = tuple(int(b) for b in bm.block_shape)
            varying = tuple(sd.shape) != block
            tag = f"in{i}" if i < n_in else f"out{i - n_in}"
            buffers.append(BlockBuffer(
                name=tag, block_shape=block, dtype=str(sd.dtype),
                bytes=_np_bytes(block, sd.dtype), grid_varying=varying))
        # scratch operands: trailing refs of the kernel jaxpr
        n_scratch = int(getattr(gm, "num_scratch_operands", 0))
        if n_scratch:
            kjaxpr = eqn.params["jaxpr"]
            for j, v in enumerate(kjaxpr.invars[-n_scratch:]):
                aval = getattr(v.aval, "inner_aval", v.aval)
                shape = tuple(int(d) for d in aval.shape)
                buffers.append(BlockBuffer(
                    name=f"scratch{j}", block_shape=shape,
                    dtype=str(aval.dtype),
                    bytes=_np_bytes(shape, aval.dtype), grid_varying=False))
        out.append(PallasLaunch(kernel_name=kname, path=path,
                                grid=tuple(int(g) for g in gm.grid),
                                buffers=tuple(buffers)))
    return out
