"""Lint suite driver (DESIGN.md §12): run every pass over every
registered executable, aggregate a report, gate CI.

The driver never raises on a violation — each (executable, pass) cell
runs independently so one broken invariant can't mask another; a crash
while BUILDING an executable becomes an "error" finding against that
executable (the lint suite must not silently skip a program that stops
lowering). ``gate()`` fails iff any unsuppressed error survives.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.executables import (Artifacts, available_executables,
                                        get_executable)
from repro.analysis.passes import (Finding, available_passes, get_pass,
                                   run_pass)

__all__ = ["format_report", "gate", "lint_table", "run_lint"]

STATIC_NEEDS = ("hlo", "jaxpr")     # artifacts obtainable by pure lowering


def _applicable(spec, pass_id: str) -> bool:
    p = get_pass(pass_id)
    if "scenario" in p.needs:
        return spec.scenario is not None
    return pass_id in spec.expect


def run_lint(*, only: Optional[Sequence[str]] = None,
             passes: Optional[Sequence[str]] = None,
             static_only: bool = False) -> List[Finding]:
    """Run the suite. ``only`` restricts executables (exact names),
    ``passes`` restricts pass ids, ``static_only`` drops scenario passes
    (pure lowering — the --lint-table mode)."""
    import jax

    names = tuple(only) if only else available_executables()
    pids = tuple(passes) if passes else available_passes()
    findings: List[Finding] = []
    for name in names:
        spec = get_executable(name)
        if spec.n_devices > jax.device_count():
            findings.append(Finding(
                pass_id="driver", severity="warning", executable=name,
                location="driver",
                message=f"skipped: needs {spec.n_devices} devices, "
                        f"{jax.device_count()} visible (set XLA_FLAGS="
                        f"--xla_force_host_platform_device_count=8)"))
            continue
        art = Artifacts(spec)
        for pid in pids:
            if static_only and "scenario" in get_pass(pid).needs:
                continue
            if not _applicable(spec, pid):
                continue
            try:
                findings.extend(run_pass(pid, spec, art))
            except Exception as e:           # build/lowering crash
                findings.append(Finding(
                    pass_id=pid, severity="error", executable=name,
                    location="driver",
                    message=f"pass crashed: {type(e).__name__}: {e}"))
    return findings


def gate(findings: Sequence[Finding]) -> Tuple[bool, str]:
    """(ok, one-line verdict): fails iff an unsuppressed error survives."""
    errs = [f for f in findings
            if f.severity == "error" and not f.suppressed]
    supp = sum(1 for f in findings if f.suppressed)
    warn = sum(1 for f in findings if f.severity == "warning")
    if errs:
        return False, (f"LINT GATE: FAIL — {len(errs)} error(s) "
                       f"({warn} warning(s), {supp} suppressed)")
    return True, (f"LINT GATE: ok — 0 errors ({warn} warning(s), "
                  f"{supp} suppressed)")


def format_report(findings: Sequence[Finding]) -> str:
    if not findings:
        return "lint: clean (no findings)"
    lines = []
    for f in sorted(findings, key=lambda f: (f.executable, f.pass_id)):
        tag = f"{f.severity}{' (suppressed)' if f.suppressed else ''}"
        lines.append(f"[{tag}] {f.executable} :: {f.pass_id}\n"
                     f"    at {f.location}\n    {f.message}")
    return "\n".join(lines)


def report_json(findings: Sequence[Finding]) -> str:
    ok, verdict = gate(findings)
    return json.dumps({"ok": ok, "verdict": verdict,
                       "findings": [f.as_dict() for f in findings]},
                      indent=2)


def lint_table(*, only: Optional[Sequence[str]] = None
               ) -> Dict[str, Dict[str, str]]:
    """pass x executable matrix of the STATIC passes (pure lowering, no
    execution): cell is "ok" | "FAIL" | "supp" | "-" (inapplicable) |
    "skip" (not enough devices). The --lint-table payload."""
    import jax

    names = tuple(only) if only else available_executables()
    static_pids = tuple(p for p in available_passes()
                        if "scenario" not in get_pass(p).needs)
    table: Dict[str, Dict[str, str]] = {}
    for name in names:
        spec = get_executable(name)
        row: Dict[str, str] = {}
        if spec.n_devices > jax.device_count():
            table[name] = {p: "skip" for p in static_pids}
            continue
        art = Artifacts(spec)
        for pid in static_pids:
            if not _applicable(spec, pid):
                row[pid] = "-"
                continue
            try:
                fs = run_pass(pid, spec, art)
            except Exception:
                row[pid] = "FAIL"
                continue
            errs = [f for f in fs if f.severity == "error"]
            if not errs:
                row[pid] = "ok"
            else:
                row[pid] = "supp" if all(f.suppressed for f in errs) \
                    else "FAIL"
        table[name] = row
    return table


def format_lint_table(table: Dict[str, Dict[str, str]]) -> str:
    if not table:
        return "(no executables)"
    pids = sorted({p for row in table.values() for p in row})
    w = max(len(n) for n in table) + 2
    hdr = "executable".ljust(w) + "".join(p.ljust(16) for p in pids)
    lines = [hdr, "-" * len(hdr)]
    for name in sorted(table):
        row = table[name]
        lines.append(name.ljust(w)
                     + "".join(row.get(p, "-").ljust(16) for p in pids))
    return "\n".join(lines)
