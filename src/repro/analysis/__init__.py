"""Compiled-program lint subsystem (DESIGN.md §12).

Static analysis over the two IRs the repo compiles through: the jaxpr
(pre-lowering truth for dtypes, pallas launches, block footprints) and
compiled HLO text (post-lowering truth for collectives). Five passes
gate the paper's structural invariants — zero-communication dropped
paths, bytes==cost-model routed paths, 16-bit dtype discipline, VMEM
residency, kernel-launch budgets, hidden host syncs — over every named
executable the system ships.

``python -m repro.launch.lint --gate`` runs the suite.

Importing this package is cheap (host-only); submodules that touch jax
import it lazily inside functions where possible.
"""
from repro.analysis.hlo import (COLLECTIVE_OPS, DTYPE_BYTES, HloInstr,
                                HloModule, UnknownDtypeError,
                                collectives_summary, parse_collectives,
                                parse_hlo, shape_bytes)

__all__ = ["COLLECTIVE_OPS", "DTYPE_BYTES", "HloInstr", "HloModule",
           "UnknownDtypeError", "collectives_summary", "parse_collectives",
           "parse_hlo", "shape_bytes"]
