from repro.training.steps import (init_train_state, make_eval_step,
                                  make_host_cond_steps, make_train_step,
                                  total_loss, xent_loss)

__all__ = ["init_train_state", "make_eval_step", "make_host_cond_steps",
           "make_train_step", "total_loss", "xent_loss"]
