from repro.training.loop import (Trainer, make_chunk_step,
                                 same_decision_runs)
from repro.training.steps import (init_train_state, make_eval_step,
                                  make_host_cond_steps, make_train_step,
                                  total_loss, xent_loss)

__all__ = ["Trainer", "init_train_state", "make_chunk_step",
           "make_eval_step", "make_host_cond_steps", "make_train_step",
           "same_decision_runs", "total_loss", "xent_loss"]
