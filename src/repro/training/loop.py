"""Scan-fused Trainer — THE training loop of the repo (DESIGN.md §8).

Seed-era training dispatched one jitted step per Python-loop iteration:
a per-step executable dispatch, a host-side consensus draw (three eager
jax calls), per-step batch synthesis, and a host sync per log interval.
The paper's claim is *wall-clock* convergence, so the host loop must not
be part of the measurement. The Trainer executes training as CHUNKS
instead: ``lax.scan`` over K steps inside a single jit, per-step metrics
accumulated on-device and fetched once per chunk, fed by the
double-buffered background prefetcher (``repro.data.prefetch``) over
vectorized batch synthesis (``repro.data.pipeline``).

Decision semantics — both bitwise-faithful to K legacy per-step calls
(asserted in ``tests/test_trainer.py``):

  traced_cond — the chunk precomputes the K consensus bits IN-GRAPH as a
      length-K vector: ``vmap`` of ``drop_decision`` over
      (seed, absolute_step) — the identical fold the per-step path uses,
      so the bits agree bitwise and stay traced (``lax.cond`` per step).
  host_cond  — the host draws the K bits (``drop_decision_host``), splits
      the chunk into MAXIMAL SAME-DECISION RUNS, and dispatches each run
      to a scan-fused executable whose decision is a static argument:
      the dropped run executable still contains zero all-to-alls
      (``tests/test_trainer.py::test_dropped_chunk_executable_has_no_alltoall``).
      jit caches one executable per (decision, run-length), so a chunk of
      K steps costs at most 2K compiles over a whole run.

Eval points are forced onto chunk ends by the schedule, so ``eval_fn``
always sees exactly the post-step params the legacy loop evaluated.
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.gating_dropout import drop_decision, drop_decisions_host
from repro.core.moe import ParallelContext
from repro.data.prefetch import Prefetcher, stack_batches
from repro.models import init_model
from repro.obs.frame import load_imbalance
from repro.obs.trace import Tracer, get_tracer, monotonic
from repro.training.steps import init_train_state, make_train_step

# tokens a step consumes: decoder tokens AND (for enc-dec tasks) encoder
# tokens — counting only "tokens" undercounted MT throughput ~2x
TOKEN_KEYS = ("tokens", "enc_tokens")


def make_chunk_step(cfg: ModelConfig, tc: TrainConfig,
                    ctx: Optional[ParallelContext] = None,
                    *, jit: bool = True) -> Callable:
    """Returns chunk_fn(state, batches, decision) -> (state, metrics).

    ``batches``: pytree with a leading K axis (``stack_batches``).
    ``metrics``: the per-step metric dict stacked to (K, ...) — fetched by
    the caller once per chunk, never per step.
    ``decision``:
      None -> traced_cond: the K consensus bits are computed in-graph
              from (seed, absolute_step) as a length-K traced vector.
      bool -> host_cond run: baked in as a static argument; jit caches
              one executable per (decision, K). With the decision static
              the dropped executable contains no all-to-all at all.
    """
    step_fn = make_train_step(cfg, tc, ctx, jit=False)
    gd = cfg.moe.gating_dropout if cfg.moe is not None else None
    use_gd = gd is not None and gd.enabled

    def chunk_fn(state, batches, decision):
        k = jax.tree.leaves(batches)[0].shape[0]
        if decision is None and use_gd:
            steps = state["step"] + jnp.arange(k, dtype=state["step"].dtype)
            decs = jax.vmap(lambda s: drop_decision(gd, tc.seed, s))(steps)

            def body(s, xs):
                b, d = xs
                return step_fn(s, b, d)

            return jax.lax.scan(body, state, (batches, decs))

        dec = bool(decision) if decision is not None else False

        def body(s, b):
            return step_fn(s, b, dec)

        return jax.lax.scan(body, state, batches)

    if jit:
        return jax.jit(chunk_fn, static_argnums=(2,), donate_argnums=(0,))
    return chunk_fn


def same_decision_runs(gd, seed: int, lo: int, hi: int
                       ) -> List[Tuple[int, int, bool]]:
    """Split [lo, hi) into maximal runs of equal host-drawn consensus bits:
    [(start, stop, decision), ...] covering the span in order. The bits
    come from ONE batched draw (``drop_decisions_host``), not per-step
    eager dispatches."""
    if gd is None or not gd.enabled:
        return [(lo, hi, False)]
    decs = [bool(d) for d in drop_decisions_host(gd, seed, lo, hi)]
    runs, i = [], 0
    while i < len(decs):
        j = i
        while j < len(decs) and decs[j] == decs[i]:
            j += 1
        runs.append((lo + i, lo + j, decs[i]))
        i = j
    return runs


class Trainer:
    """Owns a training run: state, data, chunked execution, checkpointing,
    eval, logging, and resume.

    Parameters
    ----------
    batch_fn : step -> dict of numpy arrays (one per-step batch). Called
        from the prefetch thread; must be pure host work (no jax).
    chunk : steps fused per dispatch (K). Eval points shorten individual
        chunks so they land on chunk ends.
    strategy : "traced_cond" | "host_cond" | None (None = follow
        ``cfg.moe.gating_dropout.strategy``; DESIGN.md §5).
    eval_fn : (state, step) -> dict merged into that step's history
        record; runs at chunk ends only.
    log : callable for per-record lines (default: print as JSON); None
        disables printing (history is still returned).
    """

    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 batch_fn: Callable[[int], Dict[str, np.ndarray]], *,
                 ctx: Optional[ParallelContext] = None,
                 params: Any = None,
                 chunk: int = 8,
                 strategy: Optional[str] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_meta: Optional[Dict] = None,
                 eval_every: int = 0,
                 eval_fn: Optional[Callable[[Any, int], Dict]] = None,
                 log_every: int = 20,
                 prefetch: bool = True,
                 prefetch_depth: int = 2,
                 log: Optional[Callable[[str], None]] = print,
                 tracer: Optional[Tracer] = None):
        self.cfg, self.tc, self.ctx = cfg, tc, ctx
        self.batch_fn = batch_fn
        self.chunk = max(int(chunk), 1)
        gd = cfg.moe.gating_dropout if cfg.moe is not None else None
        self.gd = gd if (gd is not None and gd.enabled) else None
        self.strategy = strategy or (self.gd.strategy if self.gd
                                     else "traced_cond")
        assert self.strategy in ("traced_cond", "host_cond"), self.strategy
        self.ckpt_dir, self.ckpt_meta = ckpt_dir, ckpt_meta
        self.eval_every, self.eval_fn = eval_every, eval_fn
        self.log_every, self.log = log_every, log
        self.prefetch, self.prefetch_depth = prefetch, prefetch_depth
        if params is None:
            params = init_model(jax.random.PRNGKey(tc.seed), cfg)
        self.state = init_train_state(params, tc)
        self.start_step = 0
        self.history: List[Dict] = []
        self.chunk_fn = make_chunk_step(cfg, tc, ctx)
        # span tracer (DESIGN.md §15): default is the process-global one
        # (disabled unless a launcher enabled it via --trace-out)
        self.tracer = tracer if tracer is not None else get_tracer()

    # ---- resume -----------------------------------------------------------
    def restore(self) -> int:
        """Restore params + opt + step from ``ckpt_dir`` and continue at
        the ABSOLUTE step: both the data stream (batch_fn) and the
        consensus PRNG (seed, step) pick up exactly where the
        checkpointed run left off (DESIGN.md §2)."""
        assert self.ckpt_dir, "restore() needs ckpt_dir"
        assert latest_step(self.ckpt_dir) is not None, \
            f"restore: no checkpoint in {self.ckpt_dir}"
        self.state, meta = restore_checkpoint(self.ckpt_dir, self.state)
        self.start_step = int(meta["step"])
        return self.start_step

    # ---- schedule ---------------------------------------------------------
    def _eval_steps(self) -> set:
        if not self.eval_every or self.eval_fn is None:
            return set()
        return ({i for i in range(self.tc.steps) if i % self.eval_every == 0}
                | {self.tc.steps - 1})

    def _record_steps(self) -> set:
        rec = {self.tc.steps - 1} | self._eval_steps()
        if self.log_every:
            rec |= {i for i in range(self.tc.steps)
                    if i % self.log_every == 0}
        return rec

    def schedule(self) -> List[Tuple[int, int]]:
        """Chunk spans [s, e) covering [start_step, steps): at most
        ``chunk`` long, cut so every eval step is a chunk's LAST step."""
        ends = sorted({i + 1 for i in self._eval_steps()} | {self.tc.steps})
        spans, s = [], self.start_step
        for e in ends:
            while s < e:
                spans.append((s, min(s + self.chunk, e)))
                s = spans[-1][1]
        return spans

    # ---- run --------------------------------------------------------------
    def _dispatch(self, span: Tuple[int, int], stacked: Dict
                  ) -> Dict[str, np.ndarray]:
        """Run one chunk; returns per-step metrics stacked over the span
        (the chunk's ONLY host-device sync, via an explicit
        jax.device_get — the analysis.hostsync guard flags implicit
        pulls inside steady-state ticks)."""
        s, e = span
        tr = self.tracer
        # jit-retrace detection: _cache_size is host-only introspection,
        # read only when tracing (it never syncs, but stays off the
        # steady path regardless)
        n0 = tr.enabled and self.chunk_fn._cache_size()
        if self.strategy == "traced_cond":
            dev = {k: jnp.asarray(v) for k, v in stacked.items()}
            with tr.span("chunk.execute", start=s, stop=e,
                         decision="traced"), \
                    tr.annotation("train_chunk"):
                self.state, ms = self.chunk_fn(self.state, dev, None)
            parts = [ms]
        else:
            parts = []
            for rs, re, dec in same_decision_runs(self.gd, self.tc.seed, s, e):
                sub = {k: jnp.asarray(v[rs - s:re - s])
                       for k, v in stacked.items()}
                with tr.span("chunk.execute", start=rs, stop=re,
                             decision=bool(dec)), \
                        tr.annotation("train_chunk"):
                    self.state, m = self.chunk_fn(self.state, sub, dec)
                parts.append(m)
        if tr.enabled and self.chunk_fn._cache_size() > n0:
            tr.instant("jit_retrace", fn="chunk_fn", start=s, stop=e)
        with tr.span("chunk.fetch", start=s, stop=e):
            parts = jax.device_get(parts)
        return {k: np.concatenate([p[k] for p in parts])
                for k in parts[0]}

    def run(self) -> Tuple[Any, List[Dict]]:
        tc = self.tc
        spans = self.schedule()
        fetch = lambda span: stack_batches(self.batch_fn, *span)  # noqa: E731
        it = (Prefetcher(fetch, spans, self.prefetch_depth,
                         tracer=self.tracer)
              if self.prefetch else map(fetch, spans))
        rec_steps, eval_steps = self._record_steps(), self._eval_steps()
        tokens_done, t0 = 0, monotonic()
        try:
            for span, stacked in zip(spans, it):
                s, e = span
                tok_per_step = sum(int(stacked[k][0].size)
                                   for k in TOKEN_KEYS if k in stacked)
                with self.tracer.span("train_chunk", start=s, stop=e,
                                      strategy=self.strategy,
                                      tokens=(e - s) * tok_per_step):
                    ms = self._dispatch(span, stacked)
                el = monotonic() - t0
                tokens_done += (e - s) * tok_per_step
                for i in range(s, e):
                    if i not in rec_steps:
                        continue
                    j = i - s
                    # tok_s pairs the CHUNK-complete token count with the
                    # chunk-boundary timestamp (el) — same convention as
                    # time_s; pro-rating tokens to step i against el would
                    # understate mid-chunk throughput
                    rec = {"step": i, "loss": float(ms["loss"][j]),
                           "acc": float(ms["acc"][j]),
                           "lr": float(ms["lr"][j]),
                           "tok_s": tokens_done / max(el, 1e-9),
                           "time_s": el}
                    if "balance" in ms:
                        rec["balance"] = float(ms["balance"][j])
                    if "comm_wire_bytes" in ms:
                        # per-device wire bytes this step's forward moved
                        # (in-graph substrate telemetry, DESIGN.md §10)
                        rec["comm_wire_bytes"] = float(
                            ms["comm_wire_bytes"][j])
                        rec["comm_a2a_calls"] = float(
                            ms["comm_a2a_calls"][j])
                        # exposed vs hidden wire (DESIGN.md §14): what an
                        # overlapped substrate could NOT pipeline behind
                        # expert compute this step
                        rec["comm_exposed_bytes"] = float(
                            ms["comm_exposed_bytes"][j])
                        rec["comm_hidden_bytes"] = float(
                            ms["comm_hidden_bytes"][j])
                    if "router_entropy" in ms:
                        # MetricsFrame router-health fields (§15): per-
                        # step entropy / load imbalance / consensus bit,
                        # already on host from the chunk fetch
                        rec["router_entropy"] = float(
                            ms["router_entropy"][j])
                        rec["load_imbalance"] = float(load_imbalance(
                            np.asarray(ms["expert_load"][j])))
                        rec["gate_dropped"] = float(ms["gate_dropped"][j])
                    if i in eval_steps:   # schedule guarantees i == e - 1
                        with self.tracer.span("eval", step=i):
                            rec.update(self.eval_fn(self.state, i))
                    self.history.append(rec)
                    if self.log is not None:
                        self.log(json.dumps(rec))
        finally:
            if isinstance(it, Prefetcher):
                it.close()
        if self.ckpt_dir:
            save_checkpoint(self.ckpt_dir, tc.steps, self.state,
                            {"arch": self.cfg.arch_id,
                             **(self.ckpt_meta or {})})
        return self.state, self.history
