"""Train / eval / serve step builders.

Gating Dropout execution strategies (DESIGN.md §5):

  traced_cond -- ONE jitted step; the per-step consensus bit is computed
                 inside the graph from (seed, step) and fed to lax.cond.
  host_cond   -- TWO jitted steps (routed / dropped); the host draws the
                 same consensus bit and dispatches. The dropped executable
                 contains no all-to-all at all (paper-faithful).

Both strategies execute the MoE layers through the backend selected by
``cfg.moe.backend`` (oracle / sharded / pallas — the registry in
core/backend.py, DESIGN.md §6): the config is threaded into every jitted
step below via model_apply -> moe_apply, so swapping backends never
requires touching the step builders.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.gating_dropout import drop_decision, drop_decision_host
from repro.core.moe import ParallelContext
from repro.models.model import model_apply
from repro.optim.adam import adam_init, adam_update

TrainState = Dict[str, Any]


def init_train_state(params, tc: TrainConfig) -> TrainState:
    return {"params": params, "opt": adam_init(params, tc),
            "step": jnp.zeros((), jnp.int32)}


def n_moe_layers(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return 0
    n = sum(1 for i in range(cfg.n_layers) if cfg.moe.is_moe_layer(i))
    if cfg.encdec is not None:
        n += sum(1 for i in range(cfg.encdec.n_encoder_layers)
                 if cfg.moe.is_moe_layer(i))
    return max(n, 1)


def xent_loss(logits: jax.Array, labels: jax.Array,
              mask: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = -(ll * mask).sum() / denom
    acc = ((logits.argmax(-1) == labels) * mask).sum() / denom
    return loss, acc


def chunked_xent(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array], chunk: int = 512
                 ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing (B, L, V) f32 logits: scan over
    sequence chunks, recompute each chunk's logits in the backward
    (jax.checkpoint). Peak logits memory: (B, chunk, V)."""
    b, l, d = hidden.shape
    if mask is None:
        mask = jnp.ones((b, l), jnp.float32)
    if l <= 2 * chunk:
        logits = (hidden.astype(head.dtype) @ head).astype(jnp.float32)
        loss, acc = xent_loss(logits, labels, mask)
        return loss, acc
    pad = (-l) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = hidden.shape[1] // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_stats(hx, lx, mx):
        logits = (hx.astype(head.dtype) @ head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lx[..., None], axis=-1)[..., 0]
        hit = (logits.argmax(-1) == lx) * mx
        return (ll * mx).sum(), hit.sum()

    def body(carry, xs):
        s, h = chunk_stats(*xs)
        return (carry[0] + s, carry[1] + h), None

    (ll_sum, hit_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (hc, lc, mc))
    denom = jnp.maximum(mask.sum(), 1.0)
    return -ll_sum / denom, hit_sum / denom


def total_loss(params, batch, cfg: ModelConfig, ctx, *, rng, decision,
               is_training=True, frame=True):
    from repro.models.model import head_matrix
    hidden, aux = model_apply(params, batch, cfg, ctx, rng=rng,
                              decision=decision, is_training=is_training,
                              return_hidden=True)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    head = head_matrix(params, cfg)
    loss, acc = chunked_xent(hidden, head, labels, mask,
                             chunk=512 if cfg.scan_layers
                             else hidden.shape[1])
    metrics = {"xent": loss, "acc": acc}
    nmoe = n_moe_layers(cfg)
    if cfg.moe is not None:
        bal = aux["balance"] / nmoe
        zl = aux["router_z"] / nmoe
        loss = loss + cfg.moe.balance_coef * bal + cfg.moe.router_z_coef * zl
        # comm_* are the substrate's in-graph transport counters
        # (DESIGN.md §10) summed over all MoE layers of THIS forward:
        # all-to-all ops, payload bytes, and per-device wire bytes the
        # step's forward pass moved (0 on Gate-Drop/local steps; the
        # backward pass doubles the wire, see comm/cost.py::step_cost)
        metrics.update(balance=bal, router_z=zl,
                       dropped_frac=aux["dropped_frac"] / nmoe,
                       comm_a2a_calls=aux["comm_a2a_calls"],
                       comm_bytes=aux["comm_bytes"],
                       comm_wire_bytes=aux["comm_wire_bytes"],
                       # §14 split: wire the chunked pipeline can hide
                       # behind expert compute vs the structurally
                       # exposed remainder (= wire for non-overlapped)
                       comm_exposed_bytes=aux["comm_exposed_bytes"],
                       comm_hidden_bytes=aux["comm_hidden_bytes"])
        if frame:
            # MetricsFrame router-health fields (DESIGN.md §15): the aux
            # values are already accumulated on device; surfacing them
            # only widens the fetched metric dict — the gate-drop
            # decision rate joins in make_train_step, where the step's
            # consensus bit is in scope
            metrics.update(expert_load=aux["load"] / nmoe,
                           router_entropy=aux["router_entropy"] / nmoe)
    if cfg.mtp and is_training and "mtp_hidden" in aux:
        labels2 = jnp.roll(labels, -1, axis=1)
        m2 = (mask if mask is not None else jnp.ones_like(labels, jnp.float32))
        m2 = m2 * jnp.roll(m2, -1, axis=1)
        m2 = m2.at[:, -1].set(0.0)
        mtp_l, _ = chunked_xent(aux["mtp_hidden"], head, labels2, m2)
        loss = loss + 0.3 * mtp_l
        metrics["mtp_xent"] = mtp_l
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    ctx: Optional[ParallelContext] = None,
                    *, jit: bool = True) -> Callable:
    """Returns train_step(state, batch, decision=None) -> (state, metrics).

    ``decision``: None -> computed in-graph from (seed, state.step)
    (traced_cond). Python bool -> baked into the executable (host_cond;
    jit caches one executable per value)."""

    frame = tc.metrics_frame

    def step_fn(state: TrainState, batch: Dict, decision) -> Tuple[TrainState, Dict]:
        step = state["step"]
        rng = jax.random.fold_in(jax.random.PRNGKey(tc.seed), step)
        if decision is None and cfg.moe is not None \
                and cfg.moe.gating_dropout.enabled:
            decision = drop_decision(cfg.moe.gating_dropout, tc.seed, step)
        grad_fn = jax.value_and_grad(
            lambda p, b, r: total_loss(p, b, cfg, ctx, rng=r,
                                       decision=decision, frame=frame),
            has_aux=True)
        k = max(tc.microbatches, 1)
        if k == 1:
            (loss, metrics), grads = grad_fn(state["params"], batch, rng)
        else:
            # gradient accumulation: scan over k microbatches (activation
            # memory / k); grads averaged, metrics averaged
            def split(x):
                b = x.shape[0]
                assert b % k == 0, (b, k)
                return x.reshape(k, b // k, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, xs):
                g_acc, m_acc, i = carry
                b_i = xs
                (_, m), g = grad_fn(state["params"], b_i,
                                    jax.random.fold_in(rng, i))
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc, i + 1), None

            (_, m0), g0 = grad_fn(state["params"],
                                  jax.tree.map(lambda x: x[0], mb),
                                  jax.random.fold_in(rng, 0))
            if cfg.scan_layers:
                (g_sum, m_sum, _), _ = jax.lax.scan(
                    acc_body, (g0, m0, 1),
                    jax.tree.map(lambda x: x[1:], mb))
            else:
                # unrolled for exact cost_analysis (scan bodies count once)
                carry = (g0, m0, 1)
                for i in range(1, k):
                    carry, _ = acc_body(
                        carry, jax.tree.map(lambda x: x[i], mb))
                g_sum, m_sum, _ = carry
            grads = jax.tree.map(lambda g: g / k, g_sum)
            metrics = jax.tree.map(lambda m: m / k, m_sum)
        new_params, new_opt, opt_m = adam_update(grads, state["opt"],
                                                 state["params"], tc)
        metrics.update(opt_m)
        if frame and cfg.moe is not None:
            # the frame's gate-drop decision-rate field: the step's
            # consensus bit as 0/1 — traced under traced_cond, a baked
            # constant under host_cond, 0 with gating dropout off
            metrics["gate_dropped"] = (
                jnp.zeros((), jnp.float32) if decision is None
                else jnp.asarray(decision, jnp.float32))
        return {"params": new_params, "opt": new_opt, "step": step + 1}, metrics

    if jit:
        return jax.jit(step_fn, static_argnums=(2,), donate_argnums=(0,))
    return step_fn


def make_host_cond_steps(cfg: ModelConfig, tc: TrainConfig,
                         ctx: Optional[ParallelContext] = None):
    """The paper-faithful strategy: two executables + a host-side chooser.

    Usage:
        step = make_host_cond_steps(cfg, tc, ctx)
        state, m = step(state, batch, host_step)   # host_step: python int
    """
    inner = make_train_step(cfg, tc, ctx, jit=True)
    gd = cfg.moe.gating_dropout if cfg.moe is not None else None

    def step(state, batch, host_step: int):
        dec = drop_decision_host(gd, tc.seed, host_step) if gd else False
        return inner(state, batch, dec)

    return step


def make_eval_step(cfg: ModelConfig, ctx=None, *, jit: bool = True):
    def eval_fn(params, batch):
        _, metrics = total_loss(params, batch, cfg, ctx, rng=None,
                                decision=False, is_training=False)
        return metrics
    return jax.jit(eval_fn) if jit else eval_fn


# NOTE: the old make_serve_step (a per-token jitted decode_step wrapper)
# is gone — all generation runs through the compiled engine in
# repro.serve (DESIGN.md §7), which loops decode_step inside one jit.
