"""Compiled decoding engine — the ONE generation loop in the repo.

Every caller that turns a model + prompt into tokens goes through here:
``launch/serve.py``, ``launch/train.py`` (BLEU eval), the examples, and
the BLEU benchmarks. The contract (DESIGN.md §7):

  * prefill writes cache positions ``[0, P)`` for a P-token prompt and
    returns the logits of position ``P-1`` — i.e. the distribution of the
    FIRST generated token. The first ``decode_step`` therefore runs at
    absolute index ``P`` (feeding the token that lives at position P),
    never at 0 — feeding index 0 after prefill overwrites the BOS slot
    and shifts every RoPE phase/mask one position early.
  * the per-token loop is a ``jax.lax.while_loop`` inside ONE jitted
    function (no per-token Python dispatch), with per-sequence EOS
    early-exit masking: once a sequence emits ``eos_id`` it produces only
    ``pad_id`` and stops counting toward ``lengths``; the loop exits as
    soon as every sequence is done.
  * hybrid archs add their meta-token offset INSIDE ``decode_step``
    (models/model.py), so callers always pass logical token positions.
  * ``ParallelContext`` and the MoE backend registry (DESIGN.md §6) are
    threaded through unchanged — decoding with ``--backend pallas``
    uses the same engine.

Greedy / temperature / top-k sampling share one loop; beam search
(``GenerateConfig.beam_width > 1``) runs a second loop that tiles the
batch to ``B*W`` rows and re-gathers every cache leaf along its batch
axis at each step (DESIGN.md §7 beam bookkeeping).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import ParallelContext
from repro.models.model import decode_step, init_cache, prefill

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    """Decoding options (hashable — baked into the jitted engine).

    temperature <= 0 means greedy argmax; ``top_k`` restricts sampling to
    the k highest logits (0 = full vocab; ``top_k=1`` == greedy).
    ``beam_width > 1`` switches to deterministic beam search (sampling
    options are ignored). ``eos_id < 0`` disables EOS early exit.
    """
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    beam_width: int = 1
    eos_id: int = 2
    pad_id: int = 0
    length_penalty: float = 1.0     # beam score norm: score / len**penalty
    early_exit: bool = True         # stop the loop when every row is done

    def __post_init__(self):
        assert self.max_new >= 1
        assert self.beam_width >= 1


class GenerateResult(NamedTuple):
    tokens: jax.Array    # (B, max_new) int32; pad_id after EOS
    lengths: jax.Array   # (B,) int32 generated tokens incl. the EOS itself
    scores: jax.Array    # (B,) f32 sum log p of emitted tokens (beam:
                         #  length-penalized best-hypothesis score)
    steps: jax.Array     # () int32 decode-loop iterations actually run


# ---------------------------------------------------------------------------
# cache batch-axis discovery (beam search re-gathers caches by parent beam)
# ---------------------------------------------------------------------------

def _cache_batch_axes(cfg: ModelConfig):
    """Per-leaf batch-axis index for the decode cache (-1 = no batch dim).

    Found structurally: build the cache at two batch sizes under
    ``eval_shape`` and diff the leaf shapes — robust to every cache family
    (full KV, ring buffer + its batchless ``pos`` leaf, MLA latents, SSM
    state, cross KV)."""
    a = jax.eval_shape(lambda: init_cache(cfg, 2, 16))
    b = jax.eval_shape(lambda: init_cache(cfg, 5, 16))

    def axis(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        assert len(diff) <= 1, (sa.shape, sb.shape)
        return diff[0] if diff else -1

    return jax.tree.map(axis, a, b)


def _gather_cache(caches, axes, idx):
    """Reorder every batched cache leaf by ``idx`` along its batch axis."""
    return jax.tree.map(
        lambda leaf, ax: leaf if ax < 0 else jnp.take(leaf, idx, axis=ax),
        caches, axes)


# ---------------------------------------------------------------------------
# token selection
# ---------------------------------------------------------------------------

def _select(gen: GenerateConfig, logits: jax.Array, rng: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """(N, V) f32 logits -> (token (N,), log p of token (N,))."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if gen.temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        scaled = logits / gen.temperature
        if gen.top_k > 0:
            kth = jax.lax.top_k(scaled, gen.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, NEG, scaled)
        tok = jax.random.categorical(rng, scaled, axis=-1)
    tok = tok.astype(jnp.int32)
    return tok, jnp.take_along_axis(logp, tok[:, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# greedy / sampling loop
# ---------------------------------------------------------------------------

def _generate_sample(params, batch, rng, cfg: ModelConfig,
                     gen: GenerateConfig, ctx) -> GenerateResult:
    prompt_len = batch["tokens"].shape[1]
    b = batch["tokens"].shape[0]
    logits0, caches = prefill(params, batch, cfg, ctx,
                              max_seq=prompt_len + gen.max_new)
    tok0, lp0 = _select(gen, logits0[:, 0].astype(jnp.float32),
                        jax.random.fold_in(rng, 0))
    done0 = (tok0 == gen.eos_id) if gen.eos_id >= 0 else jnp.zeros(b, bool)
    buf = jnp.full((b, gen.max_new), gen.pad_id, jnp.int32).at[:, 0].set(tok0)

    def cond(state):
        i, _, _, _, done, _, _ = state
        keep = i < gen.max_new
        if gen.early_exit:
            keep = keep & ~jnp.all(done)
        return keep

    def body(state):
        i, cur, caches, buf, done, length, score = state
        # ``cur`` lives at absolute position prompt_len + i - 1
        lg, caches = decode_step(params, caches, cur[:, None],
                                 prompt_len + i - 1, cfg, ctx)
        nxt, lp = _select(gen, lg[:, 0].astype(jnp.float32),
                          jax.random.fold_in(rng, i))
        nxt = jnp.where(done, gen.pad_id, nxt)
        score = score + jnp.where(done, 0.0, lp)
        length = length + jnp.where(done, 0, 1).astype(jnp.int32)
        if gen.eos_id >= 0:
            done = done | (nxt == gen.eos_id)
        buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, i))
        return i + 1, nxt, caches, buf, done, length, score

    state = (jnp.asarray(1, jnp.int32), tok0, caches, buf, done0,
             jnp.ones((b,), jnp.int32), lp0)
    i, _, _, buf, _, length, score = jax.lax.while_loop(cond, body, state)
    return GenerateResult(tokens=buf, lengths=length, scores=score,
                          steps=i - 1)


# ---------------------------------------------------------------------------
# beam search loop
# ---------------------------------------------------------------------------

def _generate_beam(params, batch, rng, cfg: ModelConfig,
                   gen: GenerateConfig, ctx) -> GenerateResult:
    del rng  # beam search is deterministic
    W = gen.beam_width
    b = batch["tokens"].shape[0]
    prompt_len = batch["tokens"].shape[1]
    axes = _cache_batch_axes(cfg)
    # Tile every prompt to W identical rows; prefill at B*W so every cache
    # leaf already carries the beam-expanded batch axis.
    tiled = {k: jnp.repeat(v, W, axis=0) for k, v in batch.items()}
    logits0, caches = prefill(params, tiled, cfg, ctx,
                              max_seq=prompt_len + gen.max_new)
    logp0 = jax.nn.log_softmax(logits0[:, 0].astype(jnp.float32), -1)
    # all W rows of a prompt are identical after prefill: seed the beams
    # with the top-W distinct first tokens of row 0
    scores, tok0 = jax.lax.top_k(logp0.reshape(b, W, -1)[:, 0], W)  # (B, W)
    tok0 = tok0.astype(jnp.int32)
    done = (tok0 == gen.eos_id) if gen.eos_id >= 0 \
        else jnp.zeros((b, W), bool)
    buf = jnp.full((b, W, gen.max_new), gen.pad_id,
                   jnp.int32).at[:, :, 0].set(tok0)
    V = logp0.shape[-1]
    # frozen-beam continuation: a finished beam re-proposes only pad_id at
    # log p = 0, so its score is carried unchanged through top-k
    frozen = jnp.full((V,), NEG, jnp.float32).at[gen.pad_id].set(0.0)

    def cond(state):
        i, _, _, _, _, done, _ = state
        keep = i < gen.max_new
        if gen.early_exit:
            keep = keep & ~jnp.all(done)
        return keep

    def body(state):
        i, cur, caches, buf, scores, done, length = state
        lg, caches = decode_step(params, caches, cur.reshape(b * W, 1),
                                 prompt_len + i - 1, cfg, ctx)
        logp = jax.nn.log_softmax(lg[:, 0].astype(jnp.float32), -1)
        logp = logp.reshape(b, W, V)
        logp = jnp.where(done[..., None], frozen[None, None], logp)
        total = (scores[..., None] + logp).reshape(b, W * V)
        scores, flat = jax.lax.top_k(total, W)                    # (B, W)
        parent = (flat // V).astype(jnp.int32)
        tok = (flat % V).astype(jnp.int32)
        # re-gather all beam state by parent
        buf = jnp.take_along_axis(buf, parent[..., None], axis=1)
        done = jnp.take_along_axis(done, parent, axis=1)
        length = jnp.take_along_axis(length, parent, axis=1)
        flat_parent = (jnp.arange(b, dtype=jnp.int32)[:, None] * W
                       + parent).reshape(-1)
        caches = _gather_cache(caches, axes, flat_parent)
        length = length + jnp.where(done, 0, 1).astype(jnp.int32)
        if gen.eos_id >= 0:
            done = done | (tok == gen.eos_id)
        buf = jax.lax.dynamic_update_slice(buf, tok[..., None], (0, 0, i))
        return i + 1, tok, caches, buf, scores, done, length

    state = (jnp.asarray(1, jnp.int32), tok0, caches, buf, scores, done,
             jnp.ones((b, W), jnp.int32))
    i, _, _, buf, scores, _, length = jax.lax.while_loop(cond, body, state)
    norm = scores / jnp.maximum(length, 1).astype(
        jnp.float32) ** gen.length_penalty
    best = jnp.argmax(norm, axis=1)
    take = lambda x: jnp.take_along_axis(
        x, best.reshape((b,) + (1,) * (x.ndim - 1)), axis=1).squeeze(1)
    return GenerateResult(tokens=take(buf), lengths=take(length),
                          scores=take(norm), steps=i - 1)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def make_generate_fn(cfg: ModelConfig, gen: GenerateConfig,
                     ctx: Optional[ParallelContext] = None):
    """Build the single-jit generation function.

    Returns ``fn(params, batch, rng=None) -> GenerateResult`` where
    ``batch`` holds the prompt ``tokens (B, P)`` plus the family's
    conditioning inputs (``enc_tokens`` / ``frames`` / ``img_embeds``).
    Prefill, the whole decode loop, and EOS bookkeeping compile into ONE
    executable per (batch shape, config)."""
    inner = _generate_beam if gen.beam_width > 1 else _generate_sample

    @jax.jit
    def fn(params, batch, rng=None):
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return inner(params, batch, rng, cfg, gen, ctx)

    return fn


@functools.lru_cache(maxsize=32)
def _cached_fn(cfg: ModelConfig, gen: GenerateConfig,
               ctx: Optional[ParallelContext]):
    return make_generate_fn(cfg, gen, ctx)


def generate(params, batch: Dict[str, Any], cfg: ModelConfig,
             gen: GenerateConfig = GenerateConfig(),
             ctx: Optional[ParallelContext] = None,
             rng: Optional[jax.Array] = None) -> GenerateResult:
    """Convenience wrapper: jitted engines are cached on (cfg, gen, ctx)."""
    return _cached_fn(cfg, gen, ctx)(params, batch, rng)
