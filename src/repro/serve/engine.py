"""Compiled decoding engine — the ONE generation loop in the repo.

Every caller that turns a model + prompt into tokens goes through here:
``launch/serve.py``, ``launch/train.py`` (BLEU eval), the examples, and
the BLEU benchmarks. The contract (DESIGN.md §7):

  * prefill writes cache positions ``[0, P)`` for a P-token prompt and
    returns the logits of position ``P-1`` — i.e. the distribution of the
    FIRST generated token. The first ``decode_step`` therefore runs at
    absolute index ``P`` (feeding the token that lives at position P),
    never at 0 — feeding index 0 after prefill overwrites the BOS slot
    and shifts every RoPE phase/mask one position early.
  * the per-token loop is a ``jax.lax.while_loop`` inside ONE jitted
    function (no per-token Python dispatch), with per-sequence EOS
    early-exit masking: once a sequence emits ``eos_id`` it produces only
    ``pad_id`` and stops counting toward ``lengths``; the loop exits as
    soon as every sequence is done.
  * hybrid archs add their meta-token offset INSIDE ``decode_step``
    (models/model.py), so callers always pass logical token positions.
  * ``ParallelContext`` and the MoE backend registry (DESIGN.md §6) are
    threaded through unchanged — decoding with ``--backend pallas``
    uses the same engine. So is the communication substrate
    (``MoEConfig.comm``, DESIGN.md §10): routed decode moves its
    dispatch/combine bytes over the configured wire, and the scheduler's
    ``tick_log`` feeds the ``launch/serve.py --trace`` comm accounting.

Since the continuous-batching refactor (DESIGN.md §9) the engine is built
from SLOT-ADDRESSED STEPWISE PRIMITIVES:

  * ``init_slot_pool``      -- persistent fixed-``max_seq`` decode cache
                               whose rows are request slots; EVERY leaf
                               carries a slot axis (the ring-buffer
                               ``pos`` leaf, batchless in the one-shot
                               cache, is batched per slot here).
  * ``prefill_into_slots``  -- prefill a group of new requests (right-
                               padded to a shared bucket length) and
                               scatter their caches into assigned slot
                               rows; returns each row's logits at its
                               TRUE last prompt token.
  * ``decode_pool_step``    -- one batched ``decode_step`` over all S
                               slots with PER-SLOT positions, so requests
                               at different depths advance together. The
                               compile count of a serving process is
                               O(prefill buckets + 1), not O(shapes).
  * ``_select_rows``        -- per-row token selection whose sampling
                               stream is keyed by (request seed, token
                               index): a request's draws are invariant to
                               its slot/batch placement.

The one-shot ``_generate_sample`` is a thin driver over these primitives
(every prompt row is a slot, all admitted at step 0); beam search
(``GenerateConfig.beam_width > 1``) keeps its bespoke loop that tiles the
batch to ``B*W`` rows and re-gathers every cache leaf along its batch
axis at each step (DESIGN.md §7 beam bookkeeping).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import ParallelContext
from repro.models.model import decode_step, init_cache, prefill

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    """Decoding options (hashable — baked into the jitted engine).

    temperature <= 0 means greedy argmax; ``top_k`` restricts sampling to
    the k highest logits (0 = full vocab; ``top_k=1`` == greedy).
    ``beam_width > 1`` switches to deterministic beam search (sampling
    options are ignored). ``eos_id < 0`` disables EOS early exit.
    ``local_routing`` reuses Gating Dropout's LOCAL routing path at decode
    time (DESIGN.md §9): MoE tokens route within the local expert group
    only, so the sharded backend's decode executable carries no
    all-to-all — the same communication the paper drops in training.
    ``flash_decode`` routes every full-cache attention read through the
    ``kernels.flash_decode`` online-softmax Pallas kernel (per-row
    positions supported; ring/window caches keep the reference path).
    """
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    beam_width: int = 1
    eos_id: int = 2
    pad_id: int = 0
    length_penalty: float = 1.0     # beam score norm: score / len**penalty
    early_exit: bool = True         # stop the loop when every row is done
    local_routing: bool = False     # Gate-Drop local path at decode (§9)
    flash_decode: bool = False      # decode attention via Pallas kernel
    max_seq: int = 0                # cache length override (0 = prompt_len
                                    # + max_new). Set to a slot pool's
                                    # max_seq to compare one-shot outputs
                                    # with pool decode BITWISE: equal cache
                                    # lengths keep every masked-softmax
                                    # reduction shape identical.

    def __post_init__(self):
        assert self.max_new >= 1
        assert self.beam_width >= 1


class GenerateResult(NamedTuple):
    tokens: jax.Array    # (B, max_new) int32; pad_id after EOS
    lengths: jax.Array   # (B,) int32 generated tokens incl. the EOS itself
    scores: jax.Array    # (B,) f32 sum log p of emitted tokens (beam:
                         #  length-penalized best-hypothesis score)
    steps: jax.Array     # () int32 decode-loop iterations actually run


# ---------------------------------------------------------------------------
# cache batch-axis discovery (beam gathers + slot-pool scatters reuse it)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _cache_batch_axes(cfg: ModelConfig):
    """Per-leaf batch-axis index for the decode cache (-1 = no batch dim).

    Found structurally: build the cache at two batch sizes under
    ``eval_shape`` and diff the leaf shapes — robust to every cache family
    (full KV, ring buffer + its batchless ``pos`` leaf, MLA latents, SSM
    state, cross KV). Memoized per ``ModelConfig``: the two eval_shape
    cache builds used to re-run on every beam-engine trace."""
    a = jax.eval_shape(lambda: init_cache(cfg, 2, 16))
    b = jax.eval_shape(lambda: init_cache(cfg, 5, 16))

    def axis(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        assert len(diff) <= 1, (sa.shape, sb.shape)
        return diff[0] if diff else -1

    return jax.tree.map(axis, a, b)


def _gather_cache(caches, axes, idx):
    """Reorder every batched cache leaf by ``idx`` along its batch axis."""
    return jax.tree.map(
        lambda leaf, ax: leaf if ax < 0 else jnp.take(leaf, idx, axis=ax),
        caches, axes)


# ---------------------------------------------------------------------------
# slot pool (continuous batching, DESIGN.md §9)
# ---------------------------------------------------------------------------

def init_slot_pool(cfg: ModelConfig, n_slots: int, max_seq: int, dtype=None):
    """Persistent slot-addressed decode cache for ``n_slots`` requests.

    Identical to ``init_cache`` except that leaves WITHOUT a batch axis
    (the ring-buffer ``pos`` leaf) gain a per-slot axis right after the
    scan-repeats axis: in a pool, every slot sits at its own depth, so
    even "shared" position bookkeeping must be per-slot. ``decode_step``
    detects the batched leaf (ndim) and takes the per-row path.

    NOTE cross-attending families size their cross-KV leaf by the
    conditioning input actually fed to ``prefill`` (which may differ from
    ``cfg.encdec.encoder_seq``); serve a trace through
    ``slot_pool_like``/``ContinuousScheduler``, which allocate the pool
    from the prefill-produced cache structure instead."""
    caches = init_cache(cfg, n_slots, max_seq, dtype)
    axes = _cache_batch_axes(cfg)

    def batch_leaf(leaf, ax):
        if ax >= 0:
            return leaf
        return jnp.broadcast_to(jnp.expand_dims(leaf, 1),
                                leaf.shape[:1] + (n_slots,) + leaf.shape[1:])

    return jax.tree.map(batch_leaf, caches, axes)


def _alloc_pool_like(fresh_shapes, axes, n_slots: int):
    """Zero slot pool whose leaves mirror a per-request cache tree with
    the batch axis resized to ``n_slots`` (unbatched leaves gain the slot
    axis after the scan-repeats axis)."""
    def alloc(fr, ax):
        if ax >= 0:
            shape = fr.shape[:ax] + (n_slots,) + fr.shape[ax + 1:]
        else:
            shape = fr.shape[:1] + (n_slots,) + fr.shape[1:]
        return jnp.zeros(shape, fr.dtype)

    return jax.tree.map(alloc, fresh_shapes, axes)


def slot_pool_like(params, batch, cfg: ModelConfig,
                   ctx: Optional[ParallelContext] = None, *,
                   max_seq: int, n_slots: int):
    """Slot pool shaped like the caches ``prefill`` will ACTUALLY produce
    for ``batch`` — cross-KV length follows the batch's conditioning
    inputs, not config defaults. Shape-only (``eval_shape``): no compute."""
    _, fresh = jax.eval_shape(
        lambda p, b: prefill(p, b, cfg, ctx, max_seq=max_seq),
        params, batch)
    return _alloc_pool_like(fresh, _cache_batch_axes(cfg), n_slots)


def _scatter_slots(pool, fresh, axes, slots):
    """Write per-request cache rows ``fresh`` into pool rows ``slots``.

    ``axes`` is the request-cache batch-axis tree (`_cache_batch_axes`);
    leaves without a batch axis live at pool axis 1 (after the scan
    repeats axis) and are broadcast to every written slot."""
    n = slots.shape[0]

    def put(pl, fr, ax):
        pool_ax = ax if ax >= 0 else 1
        if ax >= 0:
            rows = jnp.moveaxis(fr, ax, 0).astype(pl.dtype)
        else:
            rows = jnp.broadcast_to(fr.astype(pl.dtype), (n,) + fr.shape)
        pl2 = jnp.moveaxis(pl, pool_ax, 0).at[slots].set(rows)
        return jnp.moveaxis(pl2, 0, pool_ax)

    return jax.tree.map(put, pool, fresh, axes)


def prefill_into_slots(params, batch: Dict[str, Any], lengths: jax.Array,
                       slots: jax.Array, pool, cfg: ModelConfig,
                       ctx: Optional[ParallelContext] = None, *,
                       max_seq: int, rng: Optional[jax.Array] = None):
    """Prefill a group of new requests into assigned pool slots.

    ``batch["tokens"]`` is (n, bucket_len) right-padded; ``lengths`` (n,)
    are the true prompt lengths. Causal masking keeps each row's real
    positions independent of its padding, and later ``decode_pool_step``
    writes overwrite pad cache rows exactly as they would become visible,
    so padded prefill matches exact-length prefill for attention-cache
    families (SSM state integrates the pads — the scheduler prefills
    those archs at exact length instead; DESIGN.md §9).

    Returns ``(logits (n, V) at each row's last real token, pool')``."""
    logits, fresh = prefill(params, batch, cfg, ctx, max_seq=max_seq,
                            rng=rng, last_index=lengths - 1)
    pool = _scatter_slots(pool, fresh, _cache_batch_axes(cfg), slots)
    return logits[:, 0], pool


def decode_pool_step(params, pool, tok: jax.Array, pos: jax.Array,
                     alive: jax.Array, cfg: ModelConfig,
                     ctx: Optional[ParallelContext] = None, *,
                     local_routing: bool = False,
                     flash_decode: bool = False):
    """One batched ``decode_step`` over ALL pool slots at per-slot
    positions. ``tok``/``pos``/``alive`` are (S,): the token each slot
    feeds, its absolute position, and whether the slot is live (active
    and not done — dead slots still step, but ``token_valid`` keeps them
    out of expert-capacity competition and their outputs are ignored).

    Returns ``(logits (S, V), pool')``. This is the ONE decode executable
    of a serving process — compile count O(prefill buckets + 1)."""
    lg, pool = decode_step(params, pool, tok[:, None], pos, cfg, ctx,
                           local_routing=local_routing, token_valid=alive,
                           flash_decode=flash_decode)
    return lg[:, 0], pool


# ---------------------------------------------------------------------------
# token selection
# ---------------------------------------------------------------------------

def _select_rows(gen: GenerateConfig, logits: jax.Array, rng: jax.Array,
                 seeds: jax.Array, steps: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """(N, V) f32 logits -> (token (N,), log p of token (N,)).

    Sampling draws per-row keys ``fold(fold(rng, seeds[r]), steps[r])``
    (request seed x its own token index), so a request's sample stream
    does not depend on which slot it occupies or who shares the batch —
    the property continuous batching needs for placement-invariant
    outputs."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if gen.temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        scaled = logits / gen.temperature
        if gen.top_k > 0:
            kth = jax.lax.top_k(scaled, gen.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, NEG, scaled)
        keys = jax.vmap(lambda s, i: jax.random.fold_in(
            jax.random.fold_in(rng, s), i))(seeds, steps)
        tok = jax.vmap(jax.random.categorical)(keys, scaled)
    tok = tok.astype(jnp.int32)
    return tok, jnp.take_along_axis(logp, tok[:, None], axis=1)[:, 0]


def _advance(gen: GenerateConfig, nxt, lp, done, length, score):
    """Post-selection bookkeeping shared by the one-shot driver and the
    scheduler step: finished rows emit pad, stop counting, and set done
    on EOS."""
    nxt = jnp.where(done, gen.pad_id, nxt)
    score = score + jnp.where(done, 0.0, lp)
    length = length + jnp.where(done, 0, 1).astype(jnp.int32)
    if gen.eos_id >= 0:
        done = done | (nxt == gen.eos_id)
    return nxt, done, length, score


# ---------------------------------------------------------------------------
# greedy / sampling loop — thin driver over the slot-pool primitives
# ---------------------------------------------------------------------------

def _check_cache_budget(max_seq: int, prompt_len: int, max_new: int):
    """The decode cache is pinned at ``max_seq`` positions; a request that
    could outgrow it would silently wrap ``.at[index]`` writes back into
    live positions and corrupt every later read. Fail loudly instead."""
    if max_seq < prompt_len + max_new:
        raise ValueError(
            f"prompt_len ({prompt_len}) + max_new ({max_new}) = "
            f"{prompt_len + max_new} exceeds the pinned cache length "
            f"max_seq={max_seq}; raise GenerateConfig.max_seq (or lower "
            f"max_new) — the cache cannot grow after allocation")


def _generate_sample(params, batch, rng, cfg: ModelConfig,
                     gen: GenerateConfig, ctx) -> GenerateResult:
    prompt_len = batch["tokens"].shape[1]
    b = batch["tokens"].shape[0]
    max_seq = gen.max_seq or (prompt_len + gen.max_new)
    _check_cache_budget(max_seq, prompt_len, gen.max_new)
    seeds = jnp.arange(b, dtype=jnp.int32)
    lengths = jnp.full((b,), prompt_len, jnp.int32)
    # every prompt row is a slot, all admitted at step 0: the pool is
    # allocated from the prefill-produced cache structure and filled by
    # an identity scatter
    logits, fresh = prefill(params, batch, cfg, ctx, max_seq=max_seq,
                            last_index=lengths - 1)
    axes = _cache_batch_axes(cfg)
    pool = _scatter_slots(_alloc_pool_like(fresh, axes, b), fresh, axes,
                          jnp.arange(b))
    tok0, lp0 = _select_rows(gen, logits[:, 0].astype(jnp.float32), rng,
                             seeds, jnp.zeros((b,), jnp.int32))
    done0 = (tok0 == gen.eos_id) if gen.eos_id >= 0 else jnp.zeros(b, bool)
    buf = jnp.full((b, gen.max_new), gen.pad_id, jnp.int32).at[:, 0].set(tok0)
    pos0 = jnp.full((b,), prompt_len, jnp.int32)   # tok0 lives at position P

    def cond(state):
        i, _, _, _, _, done, _, _ = state
        keep = i < gen.max_new
        if gen.early_exit:
            keep = keep & ~jnp.all(done)
        return keep

    def body(state):
        i, cur, pos, pool, buf, done, length, score = state
        lg, pool = decode_pool_step(params, pool, cur, pos, ~done, cfg, ctx,
                                    local_routing=gen.local_routing,
                                    flash_decode=gen.flash_decode)
        nxt, lp = _select_rows(gen, lg.astype(jnp.float32), rng, seeds,
                               jnp.full((b,), i, jnp.int32))
        nxt, done, length, score = _advance(gen, nxt, lp, done, length,
                                            score)
        buf = jax.lax.dynamic_update_slice(buf, nxt[:, None], (0, i))
        return i + 1, nxt, pos + 1, pool, buf, done, length, score

    state = (jnp.asarray(1, jnp.int32), tok0, pos0, pool, buf, done0,
             jnp.ones((b,), jnp.int32), lp0)
    i, _, _, _, buf, _, length, score = jax.lax.while_loop(cond, body, state)
    return GenerateResult(tokens=buf, lengths=length, scores=score,
                          steps=i - 1)


# ---------------------------------------------------------------------------
# beam search loop
# ---------------------------------------------------------------------------

def _generate_beam(params, batch, rng, cfg: ModelConfig,
                   gen: GenerateConfig, ctx) -> GenerateResult:
    del rng  # beam search is deterministic
    W = gen.beam_width
    b = batch["tokens"].shape[0]
    prompt_len = batch["tokens"].shape[1]
    axes = _cache_batch_axes(cfg)
    # Tile every prompt to W identical rows; prefill at B*W so every cache
    # leaf already carries the beam-expanded batch axis.
    max_seq = gen.max_seq or (prompt_len + gen.max_new)
    _check_cache_budget(max_seq, prompt_len, gen.max_new)
    tiled = {k: jnp.repeat(v, W, axis=0) for k, v in batch.items()}
    logits0, caches = prefill(params, tiled, cfg, ctx, max_seq=max_seq)
    logp0 = jax.nn.log_softmax(logits0[:, 0].astype(jnp.float32), -1)
    # all W rows of a prompt are identical after prefill: seed the beams
    # with the top-W distinct first tokens of row 0
    scores, tok0 = jax.lax.top_k(logp0.reshape(b, W, -1)[:, 0], W)  # (B, W)
    tok0 = tok0.astype(jnp.int32)
    done = (tok0 == gen.eos_id) if gen.eos_id >= 0 \
        else jnp.zeros((b, W), bool)
    buf = jnp.full((b, W, gen.max_new), gen.pad_id,
                   jnp.int32).at[:, :, 0].set(tok0)
    V = logp0.shape[-1]
    # frozen-beam continuation: a finished beam re-proposes only pad_id at
    # log p = 0, so its score is carried unchanged through top-k
    frozen = jnp.full((V,), NEG, jnp.float32).at[gen.pad_id].set(0.0)

    def cond(state):
        i, _, _, _, _, done, _ = state
        keep = i < gen.max_new
        if gen.early_exit:
            keep = keep & ~jnp.all(done)
        return keep

    def body(state):
        i, cur, caches, buf, scores, done, length = state
        lg, caches = decode_step(params, caches, cur.reshape(b * W, 1),
                                 prompt_len + i - 1, cfg, ctx,
                                 local_routing=gen.local_routing,
                                 flash_decode=gen.flash_decode)
        logp = jax.nn.log_softmax(lg[:, 0].astype(jnp.float32), -1)
        logp = logp.reshape(b, W, V)
        logp = jnp.where(done[..., None], frozen[None, None], logp)
        total = (scores[..., None] + logp).reshape(b, W * V)
        scores, flat = jax.lax.top_k(total, W)                    # (B, W)
        parent = (flat // V).astype(jnp.int32)
        tok = (flat % V).astype(jnp.int32)
        # re-gather all beam state by parent
        buf = jnp.take_along_axis(buf, parent[..., None], axis=1)
        done = jnp.take_along_axis(done, parent, axis=1)
        length = jnp.take_along_axis(length, parent, axis=1)
        flat_parent = (jnp.arange(b, dtype=jnp.int32)[:, None] * W
                       + parent).reshape(-1)
        caches = _gather_cache(caches, axes, flat_parent)
        length = length + jnp.where(done, 0, 1).astype(jnp.int32)
        if gen.eos_id >= 0:
            done = done | (tok == gen.eos_id)
        buf = jax.lax.dynamic_update_slice(buf, tok[..., None], (0, 0, i))
        return i + 1, tok, caches, buf, scores, done, length

    state = (jnp.asarray(1, jnp.int32), tok0, caches, buf, scores, done,
             jnp.ones((b, W), jnp.int32))
    i, _, _, buf, scores, _, length = jax.lax.while_loop(cond, body, state)
    norm = scores / jnp.maximum(length, 1).astype(
        jnp.float32) ** gen.length_penalty
    best = jnp.argmax(norm, axis=1)
    take = lambda x: jnp.take_along_axis(
        x, best.reshape((b,) + (1,) * (x.ndim - 1)), axis=1).squeeze(1)
    return GenerateResult(tokens=take(buf), lengths=take(length),
                          scores=take(norm), steps=i - 1)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _check_local_routing(cfg: ModelConfig, gen: GenerateConfig):
    if (gen.local_routing and cfg.moe is not None
            and cfg.moe.gating_dropout.mode == "gate_expert_drop"):
        raise ValueError(
            "local_routing reuses the Gate-Drop LOCAL path; with "
            "gating_dropout.mode='gate_expert_drop' the dropped branch "
            "skips the MoE layer entirely — not a serving mode")


def make_generate_fn(cfg: ModelConfig, gen: GenerateConfig,
                     ctx: Optional[ParallelContext] = None):
    """Build the single-jit generation function.

    Returns ``fn(params, batch, rng=None) -> GenerateResult`` where
    ``batch`` holds the prompt ``tokens (B, P)`` plus the family's
    conditioning inputs (``enc_tokens`` / ``frames`` / ``img_embeds``).
    Prefill, the whole decode loop, and EOS bookkeeping compile into ONE
    executable per (batch shape, config)."""
    _check_local_routing(cfg, gen)
    inner = _generate_beam if gen.beam_width > 1 else _generate_sample

    @jax.jit
    def fn(params, batch, rng=None):
        if rng is None:
            rng = jax.random.PRNGKey(0)
        return inner(params, batch, rng, cfg, gen, ctx)

    return fn


@functools.lru_cache(maxsize=32)
def _cached_fn(cfg: ModelConfig, gen: GenerateConfig,
               ctx: Optional[ParallelContext]):
    return make_generate_fn(cfg, gen, ctx)


def generate(params, batch: Dict[str, Any], cfg: ModelConfig,
             gen: GenerateConfig = GenerateConfig(),
             ctx: Optional[ParallelContext] = None,
             rng: Optional[jax.Array] = None) -> GenerateResult:
    """Convenience wrapper: jitted engines are cached on (cfg, gen, ctx)."""
    return _cached_fn(cfg, gen, ctx)(params, batch, rng)
