"""Continuous-batching request scheduler (Orca/vLLM pattern, DESIGN.md §9).

The one-shot engine compiles one ``(batch, prompt_len)`` shape and runs
it start-to-finish: every request waits for the whole batch, the batch
waits for its slowest sequence, and each new shape recompiles. The
scheduler fixes all three on top of the engine's slot-pool primitives:

  * a FIFO request queue with arrival times;
  * a persistent slot pool (`engine.init_slot_pool`): each live request
    owns one slot row of the fixed-``max_seq`` decode cache;
  * length-bucketed admission: new prompts are right-padded to the
    smallest configured bucket and prefilled in fixed-width groups
    (`engine.prefill_into_slots`), so prefill compiles once per bucket;
  * one batched decode executable over ALL slots at per-slot positions
    (`engine.decode_pool_step`) — compile count O(buckets + 1);
  * mid-flight admission: a slot retires the moment its request finishes
    (EOS or per-request token budget) and is re-prefilled with the next
    queued prompt while the other slots keep decoding.

Output parity: with greedy decoding and non-binding eval expert capacity
(``eval_capacity_factor >= n_experts``), every request's tokens are
BITWISE identical to a per-request one-shot ``generate`` run against the
same cache length (``GenerateConfig(max_seq=pool max_seq)``) — asserted
in ``tests/test_scheduler.py`` and ``benchmarks/table8_serving.py``.
Sampled requests draw from per-request key streams ``fold(fold(rng,
seed), token_index)`` (engine._select_rows), so sampling is also
placement-invariant given the request's ``seed``.

Exactness policy: SSM-state archs (``cfg.ssm``) integrate right-padding
into their prefilled state, and sliding-window rings evict real tokens
when ``bucket - prompt_len`` pushes pads into the window — those configs
are prefilled at EXACT prompt length (one compile per distinct length)
instead of padded buckets. Attention-cache archs keep bucketed padding:
causal masking hides pads at prefill and pool decode overwrites each pad
cache row exactly when it would become visible.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.engine import (GenerateConfig, _check_local_routing,
                                _select_rows, decode_pool_step,
                                prefill_into_slots, slot_pool_like)


@dataclasses.dataclass
class Request:
    """One generation request. ``extras`` holds the family's conditioning
    inputs WITHOUT a batch axis (e.g. ``enc_tokens (S,)``, ``frames
    (S, d)``). ``max_new`` caps this request's generated tokens (defaults
    to the scheduler's ``GenerateConfig.max_new``); ``seed`` keys its
    sampling stream; ``arrival`` is in scheduler-clock seconds."""
    rid: int
    tokens: np.ndarray
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    max_new: Optional[int] = None
    seed: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray          # (length,) generated tokens incl. EOS
    length: int
    score: float                # sum log p of emitted tokens
    arrival: float              # scheduler-clock seconds
    admitted_at: float          # prefill started (slot assigned)
    first_token_at: float       # TTFT reference point
    finished_at: float

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.arrival

    @property
    def per_token_latency(self) -> float:
        return ((self.finished_at - self.arrival) / self.length
                if self.length else 0.0)


@functools.lru_cache(maxsize=32)
def _pool_decode_fn(cfg: ModelConfig, gen: GenerateConfig, ctx):
    """THE decode executable of a serving process (jit caches per pool
    shape). Memoized so every scheduler instance over the same config
    shares one compiled step."""
    @jax.jit
    def step(params, pool, tok, pos, alive, rng, seeds, steps):
        lg, pool = decode_pool_step(params, pool, tok, pos, alive, cfg,
                                    ctx, local_routing=gen.local_routing,
                                    flash_decode=gen.flash_decode)
        nxt, lp = _select_rows(gen, lg.astype(jnp.float32), rng, seeds,
                               steps)
        return pool, nxt, lp

    return step


@functools.lru_cache(maxsize=32)
def _bucket_prefill_fn(cfg: ModelConfig, gen: GenerateConfig, ctx,
                       max_seq: int):
    """Admission executable; jit specializes per (admit_width, bucket)
    token shape — one compile per bucket at fixed admission width."""
    @jax.jit
    def pf(params, batch, lengths, slots, pool, rng, seeds):
        logits, pool = prefill_into_slots(params, batch, lengths, slots,
                                          pool, cfg, ctx, max_seq=max_seq)
        tok0, lp0 = _select_rows(gen, logits.astype(jnp.float32), rng,
                                 seeds, jnp.zeros(lengths.shape, jnp.int32))
        return pool, tok0, lp0

    return pf


def needs_exact_prefill(cfg: ModelConfig, max_bucket: int) -> bool:
    """True when right-padded bucket prefill cannot reproduce exact-length
    prefill: SSM state integrates pads; sliding-window rings evict real
    tokens once the padded length exceeds the window."""
    if cfg.ssm is not None:
        return True
    return cfg.sliding_window > 0 and max_bucket > cfg.sliding_window


class ContinuousScheduler:
    """Slot-based continuous-batching serving loop (host-side driver).

    The device-side work is two jitted executables: one prefill per
    bucket (fixed admission width) and ONE pool decode step. The host
    keeps per-slot bookkeeping as numpy vectors, feeds them to the decode
    step each tick, and collects one token per live slot per tick."""

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 n_slots: int = 8, ctx=None,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64),
                 admit_width: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 rng: Optional[jax.Array] = None):
        assert gen.beam_width == 1, "continuous batching serves sampling/" \
            "greedy requests; beam search stays on the one-shot engine"
        _check_local_routing(cfg, gen)
        self.params = params
        self.cfg = cfg
        self.gen = gen
        self.ctx = ctx
        self.n_slots = n_slots
        self.buckets = tuple(sorted(prefill_buckets))
        self.exact_prefill = needs_exact_prefill(cfg, self.buckets[-1])
        self.admit_width = admit_width or min(4, n_slots)
        self.max_seq = max_seq or (self.buckets[-1] + gen.max_new)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        # pool row n_slots is a scratch slot: admission groups are padded
        # with dummy rows that scatter there. Allocation is deferred to
        # the first admission (slot_pool_like): cross-KV leaf length
        # follows the conditioning inputs actually served, which may
        # differ from config defaults.
        self.pool = None
        self._extras_shapes: Optional[Dict[str, Tuple]] = None
        S = n_slots + 1
        self._tok = np.zeros(S, np.int32)
        self._pos = np.zeros(S, np.int32)
        self._ngen = np.zeros(S, np.int32)
        self._active = np.zeros(S, bool)
        self._done = np.zeros(S, bool)
        self._budget = np.full(S, gen.max_new, np.int32)
        self._length = np.zeros(S, np.int32)
        self._score = np.zeros(S, np.float64)
        self._seed = np.zeros(S, np.int32)
        self._slot_rid: List[Optional[int]] = [None] * S
        self._free = deque(range(n_slots))
        self._queue: deque[Request] = deque()
        self._buffers: Dict[int, List[int]] = {}
        self._meta: Dict[int, Dict[str, float]] = {}
        self._reqs: Dict[int, Request] = {}
        self.stats = {"admitted": 0, "finished": 0, "prefill_calls": 0,
                      "decode_steps": 0, "max_concurrent": 0,
                      "slot_reuse": 0}
        # (kind, tokens) per executed device call, in order — the comm
        # accounting feed: launch/serve.py --trace prices each tick with
        # the substrate bytes model (comm/cost.py, DESIGN.md §10)
        self.tick_log: List[Tuple[str, int]] = []
        self._slot_uses = np.zeros(n_slots, np.int64)
        self._prefill = _bucket_prefill_fn(cfg, gen, ctx, self.max_seq)
        self._decode_fn = _pool_decode_fn(cfg, gen, ctx)
        # clock state so the tick API (submit + step) works without run()
        self._t0 = time.perf_counter()
        self._skip = 0.0

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request):
        assert req.tokens.ndim == 1
        if not self.exact_prefill:
            assert len(req.tokens) <= self.buckets[-1], \
                f"prompt {len(req.tokens)} exceeds largest bucket"
        budget = req.max_new or self.gen.max_new
        assert budget <= self.gen.max_new
        # holds for bucketed admission by construction (bucket + max_new
        # <= max_seq); the exact-prefill path (SSM/oversized-window) has
        # no bucket cap, and an overflow would silently drop cache writes
        assert len(req.tokens) + budget <= self.max_seq, \
            f"prompt {len(req.tokens)} + budget {budget} exceeds pool " \
            f"max_seq {self.max_seq}; raise max_seq= at scheduler init"
        self._queue.append(req)
        self._reqs[req.rid] = req
        self._meta[req.rid] = {"arrival": req.arrival}

    def _bucket(self, n: int) -> int:
        if self.exact_prefill:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError(n)

    # -- scheduling ticks ---------------------------------------------------

    def _retire(self, now: float) -> List[RequestResult]:
        out = []
        for s in range(self.n_slots):
            rid = self._slot_rid[s]
            if rid is None or not self._done[s]:
                continue
            meta = self._meta[rid]
            out.append(RequestResult(
                rid=rid, tokens=np.asarray(self._buffers[rid], np.int32),
                length=int(self._length[s]), score=float(self._score[s]),
                arrival=meta["arrival"], admitted_at=meta["admitted_at"],
                first_token_at=meta["first_token_at"], finished_at=now))
            self._slot_rid[s] = None
            self._active[s] = False
            self._done[s] = False
            self._free.append(s)
            self.stats["finished"] += 1
        return out

    def _token_done(self, tok: int, ngen: int, budget: int) -> bool:
        """One-shot `_advance` semantics: done on EOS or budget reached."""
        return (self.gen.eos_id >= 0 and tok == self.gen.eos_id) \
            or ngen >= budget

    def _admit(self, now: float):
        while self._free and self._queue \
                and self._queue[0].arrival <= now:
            # head-of-queue request sets the bucket; scan the ELIGIBLE
            # queue prefix for same-bucket peers so admission groups fill
            # up instead of fragmenting into per-request prefills (the
            # head request is always admitted — no starvation)
            bucket = self._bucket(len(self._queue[0].tokens))
            group: List[Request] = []
            skipped: List[Request] = []
            while (self._queue and len(group) < self.admit_width
                   and len(group) < len(self._free)
                   and self._queue[0].arrival <= now):
                r = self._queue.popleft()
                if self._bucket(len(r.tokens)) == bucket:
                    group.append(r)
                else:
                    skipped.append(r)
            for r in reversed(skipped):
                self._queue.appendleft(r)
            if not group:
                break
            self._prefill_group(group, bucket, now)

    def _prefill_group(self, group: List[Request], bucket: int, now: float):
        # pad the group to the next power-of-two width (<= admit_width):
        # mid-flight single-slot refills cost a width-1 prefill, not a
        # full admit_width one; compile count stays O(buckets * log W)
        W = 1
        while W < len(group):
            W *= 2
        pad = self.gen.pad_id
        tokens = np.full((W, bucket), pad, np.int32)
        lengths = np.ones(W, np.int32)
        slots = np.full(W, self.n_slots, np.int32)      # dummies -> scratch
        seeds = np.zeros(W, np.int32)
        for i, req in enumerate(group):
            tokens[i, :len(req.tokens)] = req.tokens
            lengths[i] = len(req.tokens)
            s = self._free.popleft()
            slots[i] = s
            seeds[i] = req.seed if req.seed is not None else req.rid
            self._slot_rid[s] = req.rid
            self._slot_uses[s] += 1
            if self._slot_uses[s] > 1:
                self.stats["slot_reuse"] += 1
        batch = {"tokens": jnp.asarray(tokens)}
        for k in group[0].extras:
            rows = np.stack([r.extras[k] for r in group])
            if len(group) < W:
                fill = np.zeros((W - len(group),) + rows.shape[1:],
                                rows.dtype)
                rows = np.concatenate([rows, fill], 0)
            batch[k] = jnp.asarray(rows)
        shapes = {k: tuple(v.shape[1:]) for k, v in batch.items()
                  if k != "tokens"}
        if self.pool is None:
            self._extras_shapes = shapes
            self.pool = slot_pool_like(self.params, batch, self.cfg,
                                       self.ctx, max_seq=self.max_seq,
                                       n_slots=self.n_slots + 1)
        else:
            assert shapes == self._extras_shapes, \
                "every request of a serving process must carry the same " \
                f"conditioning shapes: {shapes} != {self._extras_shapes}"
        pool, tok0, lp0 = self._prefill(
            self.params, batch, jnp.asarray(lengths), jnp.asarray(slots),
            self.pool, self.rng, jnp.asarray(seeds))
        self.pool = pool
        tok0, lp0 = jax.device_get((tok0, lp0))   # the tick's one sync
        t_first = self._now()
        for i, req in enumerate(group):
            s = int(slots[i])
            self._tok[s] = tok0[i]
            self._pos[s] = lengths[i]          # tok0 lives at position P
            self._ngen[s] = 1
            self._active[s] = True
            self._budget[s] = req.max_new or self.gen.max_new
            self._done[s] = self._token_done(int(tok0[i]), 1,
                                             int(self._budget[s]))
            self._length[s] = 1
            self._score[s] = lp0[i]
            self._seed[s] = seeds[i]
            self._buffers[req.rid] = [int(tok0[i])]
            self._meta[req.rid].update(admitted_at=now,
                                       first_token_at=t_first)
            self.stats["admitted"] += 1
        self.stats["prefill_calls"] += 1
        self.tick_log.append(("prefill", W * bucket))
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            int(self._active[:self.n_slots].sum()))

    def _decode_tick(self):
        alive = self._active & ~self._done
        if not alive[:self.n_slots].any():
            return
        pool, nxt, lp = self._decode_fn(
            self.params, self.pool, jnp.asarray(self._tok),
            jnp.asarray(self._pos), jnp.asarray(alive), self.rng,
            jnp.asarray(self._seed), jnp.asarray(self._ngen))
        self.pool = pool
        nxt, lp = jax.device_get((nxt, lp))       # the tick's one sync
        for s in range(self.n_slots):
            if not alive[s]:
                continue
            self._buffers[self._slot_rid[s]].append(int(nxt[s]))
            self._tok[s] = nxt[s]
            self._pos[s] += 1
            self._ngen[s] += 1
            self._length[s] += 1
            self._score[s] += float(lp[s])
            self._done[s] = self._token_done(int(nxt[s]),
                                             int(self._ngen[s]),
                                             int(self._budget[s]))
        self.stats["decode_steps"] += 1
        self.tick_log.append(("decode", self.n_slots + 1))

    # -- driving loop -------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0 + self._skip

    def step(self, now: float) -> List[RequestResult]:
        """One scheduler tick: retire finished slots, admit eligible
        queued requests into freed slots, run one pool decode step."""
        finished = self._retire(now)
        self._admit(now)
        self._decode_tick()
        return finished

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Serve ``requests`` (arrival-stamped) to completion. The clock
        is wall time, fast-forwarded across idle gaps between arrivals so
        sparse traces don't busy-wait."""
        for r in sorted(requests, key=lambda r: r.arrival):
            self.submit(r)
        self._t0 = time.perf_counter()
        self._skip = 0.0
        results: List[RequestResult] = []
        while self._queue or self._active[:self.n_slots].any():
            now = self._now()
            if (not self._active[:self.n_slots].any() and self._queue
                    and self._queue[0].arrival > now):
                self._skip += self._queue[0].arrival - now
                now = self._now()
            results.extend(self.step(now))
        results.extend(self._retire(self._now()))
        return sorted(results, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# static-batching baseline (table8's comparison point)
# ---------------------------------------------------------------------------

def static_batch_serve(params, cfg: ModelConfig, gen: GenerateConfig,
                       requests: Sequence[Request], *, batch_size: int,
                       ctx=None, rng: Optional[jax.Array] = None,
                       max_seq: Optional[int] = None
                       ) -> Tuple[Dict[int, np.ndarray], float]:
    """Pre-refactor serving shape: group requests FIFO into same-length
    batches of ``batch_size`` and run the one-shot engine batch by batch.
    Every batch runs until its slowest member finishes (max_new or all-
    EOS); per-request outputs are truncated to the request's budget —
    greedy decoding is prefix-stable, so truncation equals a shorter run.
    Returns ({rid: tokens}, wall_seconds)."""
    from repro.serve.engine import generate
    groups: Dict[Tuple[int, ...], List[Request]] = {}
    order: List[List[Request]] = []
    for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        key = (len(r.tokens),)
        g = groups.get(key)
        if g is None or len(g) >= batch_size:
            g = groups[key] = []
            order.append(g)
        g.append(r)
    g2 = dataclasses.replace(gen, max_seq=max_seq or gen.max_seq)
    out: Dict[int, np.ndarray] = {}
    t0 = time.perf_counter()
    for g in order:
        batch = {"tokens": jnp.asarray(np.stack([r.tokens for r in g]))}
        for k in g[0].extras:
            batch[k] = jnp.asarray(np.stack([r.extras[k] for r in g]))
        # engine instances cache on (cfg, gen, ctx) + batch shape, so a
        # warmed-up trace replay pays zero compiles
        res = jax.block_until_ready(generate(params, batch, cfg, g2, ctx,
                                             rng))
        toks = np.asarray(res.tokens)
        lens = np.asarray(res.lengths)
        for i, r in enumerate(g):
            n = min(int(lens[i]), r.max_new or gen.max_new)
            out[r.rid] = toks[i, :n]
    return out, time.perf_counter() - t0
