"""Continuous-batching request scheduler (Orca/vLLM pattern, DESIGN.md §9).

The one-shot engine compiles one ``(batch, prompt_len)`` shape and runs
it start-to-finish: every request waits for the whole batch, the batch
waits for its slowest sequence, and each new shape recompiles. The
scheduler fixes all three on top of the engine's slot-pool primitives:

  * a FIFO request queue with arrival times;
  * a persistent slot pool (`engine.init_slot_pool`): each live request
    owns one slot row of the fixed-``max_seq`` decode cache;
  * length-bucketed admission: new prompts are right-padded to the
    smallest configured bucket and prefilled in fixed-width groups
    (`engine.prefill_into_slots`), so prefill compiles once per bucket;
  * one batched decode executable over ALL slots at per-slot positions
    (`engine.decode_pool_step`) — compile count O(buckets + 1);
  * mid-flight admission: a slot retires the moment its request finishes
    (EOS or per-request token budget) and is re-prefilled with the next
    queued prompt while the other slots keep decoding.

Output parity: with greedy decoding and non-binding eval expert capacity
(``eval_capacity_factor >= n_experts``), every request's tokens are
BITWISE identical to a per-request one-shot ``generate`` run against the
same cache length (``GenerateConfig(max_seq=pool max_seq)``) — asserted
in ``tests/test_scheduler.py`` and ``benchmarks/table8_serving.py``.
Sampled requests draw from per-request key streams ``fold(fold(rng,
seed), token_index)`` (engine._select_rows), so sampling is also
placement-invariant given the request's ``seed``.

Exactness policy: SSM-state archs (``cfg.ssm``) integrate right-padding
into their prefilled state, and sliding-window rings evict real tokens
when ``bucket - prompt_len`` pushes pads into the window — those configs
are prefilled at EXACT prompt length (one compile per distinct length)
instead of padded buckets. Attention-cache archs keep bucketed padding:
causal masking hides pads at prefill and pool decode overwrites each pad
cache row exactly when it would become visible.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PagedKVConfig
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer, get_tracer, monotonic
from repro.serve.engine import (GenerateConfig, _check_local_routing,
                                _select_rows, decode_pool_step,
                                prefill_into_slots, slot_pool_like)
from repro.serve.paged import (PageAllocator, PagedLayout, PagePoolExhausted,
                               PrefixCache, _cache_page_axes, ceil_div,
                               copy_pages, decode_paged_step,
                               gather_slot_state, paged_kv_bytes,
                               paged_pool_like, prefill_into_pages,
                               restore_slot_state)


@dataclasses.dataclass
class Request:
    """One generation request. ``extras`` holds the family's conditioning
    inputs WITHOUT a batch axis (e.g. ``enc_tokens (S,)``, ``frames
    (S, d)``). ``max_new`` caps this request's generated tokens (defaults
    to the scheduler's ``GenerateConfig.max_new``); ``seed`` keys its
    sampling stream; ``arrival`` is in scheduler-clock seconds."""
    rid: int
    tokens: np.ndarray
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    max_new: Optional[int] = None
    seed: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray          # (length,) generated tokens incl. EOS
    length: int
    score: float                # sum log p of emitted tokens
    arrival: float              # scheduler-clock seconds
    admitted_at: float          # prefill started (slot assigned)
    first_token_at: float       # TTFT reference point
    finished_at: float

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.arrival

    @property
    def per_token_latency(self) -> float:
        return ((self.finished_at - self.arrival) / self.length
                if self.length else 0.0)


@functools.lru_cache(maxsize=32)
def _pool_decode_fn(cfg: ModelConfig, gen: GenerateConfig, ctx):
    """THE decode executable of a serving process (jit caches per pool
    shape). Memoized so every scheduler instance over the same config
    shares one compiled step."""
    @jax.jit
    def step(params, pool, tok, pos, alive, rng, seeds, steps):
        lg, pool = decode_pool_step(params, pool, tok, pos, alive, cfg,
                                    ctx, local_routing=gen.local_routing,
                                    flash_decode=gen.flash_decode)
        nxt, lp = _select_rows(gen, lg.astype(jnp.float32), rng, seeds,
                               steps)
        return pool, nxt, lp

    return step


@functools.lru_cache(maxsize=32)
def _bucket_prefill_fn(cfg: ModelConfig, gen: GenerateConfig, ctx,
                       max_seq: int):
    """Admission executable; jit specializes per (admit_width, bucket)
    token shape — one compile per bucket at fixed admission width."""
    @jax.jit
    def pf(params, batch, lengths, slots, pool, rng, seeds):
        logits, pool = prefill_into_slots(params, batch, lengths, slots,
                                          pool, cfg, ctx, max_seq=max_seq)
        tok0, lp0 = _select_rows(gen, logits.astype(jnp.float32), rng,
                                 seeds, jnp.zeros(lengths.shape, jnp.int32))
        return pool, tok0, lp0

    return pf


def needs_exact_prefill(cfg: ModelConfig, max_bucket: int) -> bool:
    """True when right-padded bucket prefill cannot reproduce exact-length
    prefill: SSM state integrates pads; sliding-window rings evict real
    tokens once the padded length exceeds the window."""
    if cfg.ssm is not None:
        return True
    return cfg.sliding_window > 0 and max_bucket > cfg.sliding_window


class ContinuousScheduler:
    """Slot-based continuous-batching serving loop (host-side driver).

    The device-side work is two jitted executables: one prefill per
    bucket (fixed admission width) and ONE pool decode step. The host
    keeps per-slot bookkeeping as numpy vectors, feeds them to the decode
    step each tick, and collects one token per live slot per tick."""

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 n_slots: int = 8, ctx=None,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64),
                 admit_width: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 rng: Optional[jax.Array] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        assert gen.beam_width == 1, "continuous batching serves sampling/" \
            "greedy requests; beam search stays on the one-shot engine"
        _check_local_routing(cfg, gen)
        self.params = params
        self.cfg = cfg
        self.gen = gen
        self.ctx = ctx
        self.n_slots = n_slots
        self.buckets = tuple(sorted(prefill_buckets))
        self.exact_prefill = needs_exact_prefill(cfg, self.buckets[-1])
        self.admit_width = admit_width or min(4, n_slots)
        self.max_seq = max_seq or (self.buckets[-1] + gen.max_new)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        # pool row n_slots is a scratch slot: admission groups are padded
        # with dummy rows that scatter there. Allocation is deferred to
        # the first admission (slot_pool_like): cross-KV leaf length
        # follows the conditioning inputs actually served, which may
        # differ from config defaults.
        self.pool = None
        self._extras_shapes: Optional[Dict[str, Tuple]] = None
        S = n_slots + 1
        self._tok = np.zeros(S, np.int32)
        self._pos = np.zeros(S, np.int32)
        self._ngen = np.zeros(S, np.int32)
        self._active = np.zeros(S, bool)
        self._done = np.zeros(S, bool)
        self._budget = np.full(S, gen.max_new, np.int32)
        self._length = np.zeros(S, np.int32)
        self._score = np.zeros(S, np.float64)
        self._seed = np.zeros(S, np.int32)
        self._slot_rid: List[Optional[int]] = [None] * S
        self._free = deque(range(n_slots))
        self._queue: deque[Request] = deque()
        self._buffers: Dict[int, List[int]] = {}
        self._meta: Dict[int, Dict[str, float]] = {}
        self._reqs: Dict[int, Request] = {}
        self.stats = {"admitted": 0, "finished": 0, "prefill_calls": 0,
                      "decode_steps": 0, "max_concurrent": 0,
                      "slot_reuse": 0}
        # observability (DESIGN.md §15): one registry backs every serving
        # metric of this scheduler — the legacy tick_log/alive_log
        # attributes are live views over two registry Series, and TTFT /
        # per-token latency land in registry histograms at retire time
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        # (kind, tokens) per executed device call, in order — the comm
        # accounting feed: launch/serve.py --trace prices each tick with
        # the substrate bytes model (comm/cost.py, DESIGN.md §10)
        self._ticks = self.metrics.series(
            "serve/tick_log", "device calls: label=kind, value=tokens")
        # live-slot count per decode tick: the sustained-concurrency
        # series benchmarks/table10_paged.py compares across cache layouts
        self._alive_series = self.metrics.series(
            "serve/alive_log", "live slots per decode tick")
        self._ttft = self.metrics.histogram(
            "serve/ttft_s", "arrival -> first token, seconds")
        self._lat = self.metrics.histogram(
            "serve/per_token_latency_s", "request seconds per token")
        self._slot_uses = np.zeros(n_slots, np.int64)
        self._prefill = _bucket_prefill_fn(cfg, gen, ctx, self.max_seq)
        self._decode_fn = _pool_decode_fn(cfg, gen, ctx)
        # clock state so the tick API (submit + step) works without run()
        self._t0 = monotonic()
        self._skip = 0.0

    # -- legacy metric views (exact aliases of the registry Series) ---------

    @property
    def tick_log(self) -> List[Tuple[str, int]]:
        return self._ticks.items

    @property
    def alive_log(self) -> List[int]:
        return self._alive_series.values

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request):
        assert req.tokens.ndim == 1
        if not self.exact_prefill and len(req.tokens) > self.buckets[-1]:
            raise ValueError(
                f"prompt length {len(req.tokens)} exceeds the largest "
                f"prefill bucket {self.buckets[-1]}; add a larger bucket "
                f"at scheduler init")
        budget = req.max_new or self.gen.max_new
        if budget > self.gen.max_new:
            raise ValueError(
                f"request max_new {budget} exceeds the scheduler's "
                f"GenerateConfig.max_new {self.gen.max_new}")
        # holds for bucketed admission by construction (bucket + max_new
        # <= max_seq); the exact-prefill path (SSM/oversized-window) has
        # no bucket cap, and an overflow would silently wrap cache writes
        # back into live positions — fail loudly up front instead
        if len(req.tokens) + budget > self.max_seq:
            raise ValueError(
                f"prompt {len(req.tokens)} + budget {budget} exceeds the "
                f"pinned pool cache length max_seq={self.max_seq}; raise "
                f"max_seq= at scheduler init — the pool cannot grow")
        self._queue.append(req)
        self._reqs[req.rid] = req
        self._meta[req.rid] = {"arrival": req.arrival}

    def _bucket(self, n: int) -> int:
        if self.exact_prefill:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError(n)

    # -- scheduling ticks ---------------------------------------------------

    def _retire(self, now: float) -> List[RequestResult]:
        out = []
        for s in range(self.n_slots):
            rid = self._slot_rid[s]
            if rid is None or not self._done[s]:
                continue
            meta = self._meta[rid]
            res = RequestResult(
                rid=rid, tokens=np.asarray(self._buffers[rid], np.int32),
                length=int(self._length[s]), score=float(self._score[s]),
                arrival=meta["arrival"], admitted_at=meta["admitted_at"],
                first_token_at=meta["first_token_at"], finished_at=now)
            out.append(res)
            self._ttft.observe(res.ttft)
            self._lat.observe(res.per_token_latency)
            self._slot_rid[s] = None
            self._active[s] = False
            self._done[s] = False
            self._free.append(s)
            self.stats["finished"] += 1
        return out

    def _token_done(self, tok: int, ngen: int, budget: int) -> bool:
        """One-shot `_advance` semantics: done on EOS or budget reached."""
        return (self.gen.eos_id >= 0 and tok == self.gen.eos_id) \
            or ngen >= budget

    def _can_admit(self, req: Request) -> bool:
        """Admission gate hook beyond slot availability — the base
        scheduler admits whenever a slot is free; the paged scheduler
        overrides this with free-page accounting (reserving the pages as
        a side effect, so a True answer cannot fail later)."""
        return True

    def _admit(self, now: float):
        if not (self._free and self._queue
                and self._queue[0].arrival <= now):
            return
        with self.tracer.span("sched.admit", queued=len(self._queue)):
            self._admit_loop(now)

    def _admit_loop(self, now: float):
        while self._free and self._queue \
                and self._queue[0].arrival <= now:
            # head-of-queue request sets the bucket; scan the ELIGIBLE
            # queue prefix for same-bucket peers so admission groups fill
            # up instead of fragmenting into per-request prefills (the
            # head request is always admitted — no starvation)
            if not self._can_admit(self._queue[0]):
                break                     # backpressure: keep FIFO order
            bucket = self._bucket(len(self._queue[0].tokens))
            group: List[Request] = []
            skipped: List[Request] = []
            while (self._queue and len(group) < self.admit_width
                   and len(group) < len(self._free)
                   and self._queue[0].arrival <= now):
                r = self._queue.popleft()
                if self._bucket(len(r.tokens)) == bucket \
                        and (group == [] or self._can_admit(r)):
                    group.append(r)
                else:
                    skipped.append(r)
            for r in reversed(skipped):
                self._queue.appendleft(r)
            if not group:
                break
            self._prefill_group(group, bucket, now)

    def _stage_group(self, group: List[Request], bucket: int):
        """Host-side admission staging shared by the slot-pool and paged
        schedulers: pad the group to the next power-of-two width (<=
        admit_width) so mid-flight single-slot refills cost a width-1
        prefill, not a full admit_width one (compile count stays
        O(buckets * log W)); assign freed slots; build the device batch."""
        W = 1
        while W < len(group):
            W *= 2
        pad = self.gen.pad_id
        tokens = np.full((W, bucket), pad, np.int32)
        lengths = np.ones(W, np.int32)
        slots = np.full(W, self.n_slots, np.int32)      # dummies -> scratch
        seeds = np.zeros(W, np.int32)
        for i, req in enumerate(group):
            tokens[i, :len(req.tokens)] = req.tokens
            lengths[i] = len(req.tokens)
            s = self._free.popleft()
            slots[i] = s
            seeds[i] = req.seed if req.seed is not None else req.rid
            self._slot_rid[s] = req.rid
            self._slot_uses[s] += 1
            if self._slot_uses[s] > 1:
                self.stats["slot_reuse"] += 1
        batch = {"tokens": jnp.asarray(tokens)}
        for k in group[0].extras:
            rows = np.stack([r.extras[k] for r in group])
            if len(group) < W:
                fill = np.zeros((W - len(group),) + rows.shape[1:],
                                rows.dtype)
                rows = np.concatenate([rows, fill], 0)
            batch[k] = jnp.asarray(rows)
        self._ensure_pool(batch)
        return W, lengths, slots, seeds, batch

    def _alloc_pool(self, batch):
        return slot_pool_like(self.params, batch, self.cfg, self.ctx,
                              max_seq=self.max_seq,
                              n_slots=self.n_slots + 1)

    def _ensure_pool(self, batch):
        shapes = {k: tuple(v.shape[1:]) for k, v in batch.items()
                  if k != "tokens"}
        if self.pool is None:
            self._extras_shapes = shapes
            self.pool = self._alloc_pool(batch)
        else:
            assert shapes == self._extras_shapes, \
                "every request of a serving process must carry the same " \
                f"conditioning shapes: {shapes} != {self._extras_shapes}"

    def _finish_admission(self, group: List[Request], bucket: int, W: int,
                          lengths, slots, seeds, tok0, lp0, now: float):
        """Per-slot host bookkeeping once the admission prefill's first
        tokens are on the host."""
        t_first = self._now()
        for i, req in enumerate(group):
            s = int(slots[i])
            self._tok[s] = tok0[i]
            self._pos[s] = lengths[i]          # tok0 lives at position P
            self._ngen[s] = 1
            self._active[s] = True
            self._budget[s] = req.max_new or self.gen.max_new
            self._done[s] = self._token_done(int(tok0[i]), 1,
                                             int(self._budget[s]))
            self._length[s] = 1
            self._score[s] = lp0[i]
            self._seed[s] = seeds[i]
            self._buffers[req.rid] = [int(tok0[i])]
            self._meta[req.rid].update(admitted_at=now,
                                       first_token_at=t_first)
            self.stats["admitted"] += 1
        self.stats["prefill_calls"] += 1
        self._ticks.append(W * bucket, label="prefill")
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            int(self._active[:self.n_slots].sum()))

    def _prefill_group(self, group: List[Request], bucket: int, now: float):
        with self.tracer.span("sched.prefill", bucket=bucket,
                              group=len(group)):
            W, lengths, slots, seeds, batch = self._stage_group(group, bucket)
            pool, tok0, lp0 = self._prefill(
                self.params, batch, jnp.asarray(lengths), jnp.asarray(slots),
                self.pool, self.rng, jnp.asarray(seeds))
            self.pool = pool
            tok0, lp0 = jax.device_get((tok0, lp0))   # the tick's one sync
            self._finish_admission(group, bucket, W, lengths, slots, seeds,
                                   tok0, lp0, now)

    def _decode_call(self, alive):
        """Launch the pool decode executable (overridden by the paged
        scheduler to feed block tables); returns (nxt, lp) device arrays
        and reassigns ``self.pool``."""
        pool, nxt, lp = self._decode_fn(
            self.params, self.pool, jnp.asarray(self._tok),
            jnp.asarray(self._pos), jnp.asarray(alive), self.rng,
            jnp.asarray(self._seed), jnp.asarray(self._ngen))
        self.pool = pool
        return nxt, lp

    def _decode_tick(self):
        alive = self._active & ~self._done
        if not alive[:self.n_slots].any():
            return
        with self.tracer.span("sched.decode",
                              alive=int(alive[:self.n_slots].sum())):
            self._decode_tick_body(alive)

    def _decode_tick_body(self, alive):
        nxt, lp = self._decode_call(alive)
        # recompute: paged page-exhaustion preemption can deactivate slots
        # inside the decode call (their rows decode dead, outputs ignored)
        alive = self._active & ~self._done
        self._alive_series.append(int(alive[:self.n_slots].sum()))
        nxt, lp = jax.device_get((nxt, lp))       # the tick's one sync
        for s in range(self.n_slots):
            if not alive[s]:
                continue
            self._buffers[self._slot_rid[s]].append(int(nxt[s]))
            self._tok[s] = nxt[s]
            self._pos[s] += 1
            self._ngen[s] += 1
            self._length[s] += 1
            self._score[s] += float(lp[s])
            self._done[s] = self._token_done(int(nxt[s]),
                                             int(self._ngen[s]),
                                             int(self._budget[s]))
        self.stats["decode_steps"] += 1
        self._ticks.append(self.n_slots + 1, label="decode")

    # -- driving loop -------------------------------------------------------

    def _now(self) -> float:
        return monotonic() - self._t0 + self._skip

    def step(self, now: float) -> List[RequestResult]:
        """One scheduler tick: retire finished slots, admit eligible
        queued requests into freed slots, run one pool decode step."""
        finished = self._retire(now)
        self._admit(now)
        self._decode_tick()
        return finished

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Serve ``requests`` (arrival-stamped) to completion. The clock
        is wall time, fast-forwarded across idle gaps between arrivals so
        sparse traces don't busy-wait."""
        for r in sorted(requests, key=lambda r: r.arrival):
            self.submit(r)
        self._t0 = monotonic()
        self._skip = 0.0
        results: List[RequestResult] = []
        while self._queue or self._active[:self.n_slots].any():
            now = self._now()
            if (not self._active[:self.n_slots].any() and self._queue
                    and self._queue[0].arrival > now):
                self._skip += self._queue[0].arrival - now
                now = self._now()
            results.extend(self.step(now))
        results.extend(self._retire(self._now()))
        return sorted(results, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# paged scheduler (block-table addressed KV, DESIGN.md §13)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _paged_decode_fn(cfg: ModelConfig, gen: GenerateConfig, ctx):
    """THE decode executable of a paged serving process — the slot-pool
    twin of `_pool_decode_fn` plus the block-table operand."""
    @jax.jit
    def step(params, pool, tables, tok, pos, alive, rng, seeds, steps):
        lg, pool = decode_paged_step(params, pool, tables, tok, pos, alive,
                                     cfg, ctx,
                                     local_routing=gen.local_routing,
                                     flash_decode=gen.flash_decode)
        nxt, lp = _select_rows(gen, lg.astype(jnp.float32), rng, seeds,
                               steps)
        return pool, nxt, lp

    return step


@functools.lru_cache(maxsize=32)
def _paged_prefill_fn(cfg: ModelConfig, gen: GenerateConfig, ctx,
                      max_seq: int, layout: PagedLayout):
    @jax.jit
    def pf(params, batch, lengths, write_tables, slot_rows, pool, rng,
           seeds):
        logits, pool = prefill_into_pages(
            params, batch, lengths, write_tables, slot_rows, pool, cfg,
            ctx, max_seq=max_seq, layout=layout)
        tok0, lp0 = _select_rows(gen, logits.astype(jnp.float32), rng,
                                 seeds, jnp.zeros(lengths.shape, jnp.int32))
        return pool, tok0, lp0

    return pf


@functools.lru_cache(maxsize=8)
def _copy_pages_fn(cfg: ModelConfig):
    @jax.jit
    def cp(pool, src, dst):
        return copy_pages(pool, cfg, src, dst)

    return cp


@functools.lru_cache(maxsize=8)
def _gather_slot_fn(cfg: ModelConfig):
    @jax.jit
    def g(pool, table_row, slot):
        return gather_slot_state(pool, cfg, table_row, slot)

    return g


@functools.lru_cache(maxsize=8)
def _restore_slot_fn(cfg: ModelConfig):
    @jax.jit
    def r(pool, saved, table_row, slot):
        return restore_slot_state(pool, cfg, saved, table_row, slot)

    return r


@dataclasses.dataclass
class _SwapState:
    """Host snapshot of a preempted slot: the per-slot scheduler scalars
    plus the device cache state (its pages, page-major, and its
    slot-addressed leaf rows) pulled to host memory."""
    tok: int
    pos: int
    ngen: int
    budget: int
    length: int
    score: float
    seed: int
    saved: object


class PagedScheduler(ContinuousScheduler):
    """Continuous batching over a paged KV cache (DESIGN.md §13).

    Same host driver as `ContinuousScheduler`, three paged behaviours on
    top, all through the base class's hook methods:

      * ADMISSION BY FREE PAGES (`_can_admit`): a request is admitted only
        when its prompt's pages (minus prefix-cache hits) fit in the free
        list with `reserve_pages` headroom; reservation happens inside the
        gate so a True answer cannot fail later. Backpressure keeps FIFO
        order — the queue head blocks admission until pages free up.
      * PREFIX SHARING: full prompt pages (and whole identical prompts)
        are published to a `PrefixCache` after prefill; later requests
        point their leading block-table entries at the shared pages and
        skip re-writing them. First divergent write => COW.
      * COPY-ON-WRITE + PREEMPTION (`_ensure_writable`): before each
        decode tick every live slot's write-block must be private and
        real. A shared write-page is copied (batched `copy_pages`, padded
        to a power-of-two pair count); page exhaustion evicts cache
        entries, then preempts the youngest-admitted live slot — swap-OUT
        to host memory, not recompute, so re-admitted requests keep
        bitwise-identical outputs.

    Host syncs: one `device_get` per tick on the steady path (inherited
    from the base scheduler); preemption swap-out adds one exceptional
    gather sync, which the analysis-lint scenario deliberately avoids.
    """

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 paged: PagedKVConfig = PagedKVConfig(),
                 n_slots: int = 8, ctx=None,
                 prefill_buckets: Sequence[int] = (8, 16, 32, 64),
                 admit_width: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 rng: Optional[jax.Array] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        super().__init__(params, cfg, gen, n_slots=n_slots, ctx=ctx,
                         prefill_buckets=prefill_buckets,
                         admit_width=admit_width, max_seq=max_seq, rng=rng,
                         registry=registry, tracer=tracer)
        _, seq_axes = _cache_page_axes(cfg)
        if not any(a >= 0 for a in jax.tree.leaves(seq_axes)):
            raise ValueError(
                f"{cfg.arch_id}: no cache leaf tracks max_seq (pure "
                "SSM/ring cache) — nothing to page; use "
                "ContinuousScheduler")
        self.paged = paged
        ps = paged.page_size
        self._n_meta = (cfg.hybrid.n_meta_tokens
                        if cfg.hybrid is not None else 0)
        seq_len = self.max_seq + self._n_meta
        n_blocks = ceil_div(seq_len, ps)
        n_pages = paged.n_pages or paged.n_slots_equiv * n_blocks
        if n_pages < n_blocks + paged.reserve_pages:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one full-length request "
                f"({n_blocks} blocks of {ps}) plus reserve_pages="
                f"{paged.reserve_pages}; the scheduler could deadlock")
        self.layout = PagedLayout(page_size=ps, n_pages=n_pages,
                                  seq_len=seq_len)
        self._pages = PageAllocator(n_pages)
        self._prefix = (PrefixCache(self._pages)
                        if paged.prefix_caching else None)
        # rid -> (reserved page list, #prefix-shared prefix) while the
        # request sits between its _can_admit reservation and its prefill
        self._plans: Dict[int, Tuple[List[int], int]] = {}
        self._swapped: Dict[int, _SwapState] = {}
        self._cow_src: List[int] = []
        self._cow_dst: List[int] = []
        self._tables = np.full((n_slots + 1, n_blocks),
                               self.layout.scratch, np.int32)
        self.stats.update(prefix_lookups=0, prefix_hits=0, cow_copies=0,
                          preemptions=0, swap_ins=0, peak_pages_in_use=0)
        self._prefill = _paged_prefill_fn(cfg, gen, ctx, self.max_seq,
                                          self.layout)
        self._decode_fn = _paged_decode_fn(cfg, gen, ctx)
        self._copy = _copy_pages_fn(cfg)
        self._gather = _gather_slot_fn(cfg)
        self._restore = _restore_slot_fn(cfg)

    # -- page accounting ----------------------------------------------------

    @property
    def page_bytes(self) -> int:
        """Bytes one physical page pins across the pageable leaves."""
        if self.pool is None:
            return 0
        return paged_kv_bytes(self.pool, self.cfg) \
            // (self.layout.n_pages + 1)

    # note: no submit() page-budget override is needed — the base class's
    # ``prompt + budget <= max_seq`` check plus the __init__ deadlock
    # check (``n_pages >= n_blocks + reserve_pages``) together bound any
    # accepted request's worst-case page need by the arena size

    def _page_or_none(self) -> Optional[int]:
        """try_alloc with prefix-cache eviction pressure."""
        p = self._pages.try_alloc()
        while p is None and self._prefix is not None \
                and self._prefix.evict_one():
            p = self._pages.try_alloc()
        return p

    def _free_capacity(self) -> int:
        ev = (self._prefix.evictable_pages()
              if self._prefix is not None else 0)
        return self._pages.n_free + ev

    def _note_pages(self):
        self.stats["peak_pages_in_use"] = max(
            self.stats["peak_pages_in_use"], self._pages.in_use())

    def _page_key(self, tokens: np.ndarray, f: int):
        """Key of the first ``f`` full pages: page f-1 ends at logical
        position f*ps - 1, which depends on tokens up to index
        f*ps - n_meta - 1 (meta tokens occupy the first logical slots)."""
        cut = max(0, f * self.layout.page_size - self._n_meta)
        return ("PG", f, tokens[:cut].tobytes())

    def _full_key(self, tokens: np.ndarray):
        return ("FULL", len(tokens), tokens.tobytes())

    def _slot_pages(self, s: int) -> List[int]:
        scratch = self.layout.scratch
        return [int(p) for p in self._tables[s] if p != scratch]

    def _release_slot_pages(self, s: int):
        for p in self._slot_pages(s):
            self._pages.decref(p)
        self._tables[s] = self.layout.scratch

    # -- admission ----------------------------------------------------------

    def _can_admit(self, req: Request) -> bool:
        if req.rid in self._plans:      # re-asked within the same tick
            return True
        tokens = np.asarray(req.tokens, np.int32)
        need = self.layout.pages_for(len(tokens) + self._n_meta)
        shared: List[int] = []
        if self._prefix is not None:
            self.stats["prefix_lookups"] += 1
            self._prefix.lookups += 1
            hit = self._prefix.get(self._full_key(tokens))
            if hit is None:
                f_max = (len(tokens) + self._n_meta) \
                    // self.layout.page_size
                for f in range(f_max, 0, -1):
                    hit = self._prefix.get(self._page_key(tokens, f))
                    if hit is not None:
                        break
            if hit is not None:
                shared = list(hit)
                self.stats["prefix_hits"] += 1
                self._prefix.hits += 1
                self.tracer.instant("prefix_cache.hit", rid=req.rid,
                                    shared_pages=len(shared))
            else:
                self.tracer.instant("prefix_cache.miss", rid=req.rid)
        n_fresh = need - len(shared)
        if self._free_capacity() < n_fresh + self.paged.reserve_pages:
            return False                # backpressure
        for p in shared:
            self._pages.incref(p)
        fresh = [self._page_or_none() for _ in range(n_fresh)]
        assert all(p is not None for p in fresh)  # capacity checked above
        self._plans[req.rid] = (shared + fresh, len(shared))
        return True

    def _alloc_pool(self, batch):
        return paged_pool_like(self.params, batch, self.cfg, self.ctx,
                               max_seq=self.max_seq,
                               n_slots=self.n_slots + 1,
                               layout=self.layout)

    def _prefill_group(self, group: List[Request], bucket: int, now: float):
        W, lengths, slots, seeds, batch = self._stage_group(group, bucket)
        nb, scratch = self.layout.n_blocks, self.layout.scratch
        wt = np.full((W, nb), scratch, np.int32)
        for i, req in enumerate(group):
            pages, h = self._plans.pop(req.rid)
            s = int(slots[i])
            self._tables[s] = scratch
            self._tables[s, :len(pages)] = pages
            wt[i, h:len(pages)] = pages[h:]     # shared blocks stay scratch
        pool, tok0, lp0 = self._prefill(
            self.params, batch, jnp.asarray(lengths), jnp.asarray(wt),
            jnp.asarray(slots), self.pool, self.rng, jnp.asarray(seeds))
        self.pool = pool
        tok0, lp0 = jax.device_get((tok0, lp0))   # the tick's one sync
        if self._prefix is not None:
            for i, req in enumerate(group):
                tokens = np.asarray(req.tokens, np.int32)
                need = self.layout.pages_for(len(tokens) + self._n_meta)
                pages = [int(p) for p in self._tables[int(slots[i])][:need]]
                f_max = (len(tokens) + self._n_meta) \
                    // self.layout.page_size
                for f in range(1, f_max + 1):
                    self._prefix.put(self._page_key(tokens, f), pages[:f])
                self._prefix.put(self._full_key(tokens), pages)
        self._finish_admission(group, bucket, W, lengths, slots, seeds,
                               tok0, lp0, now)
        self._note_pages()

    def _try_swap_in(self, req: Request) -> bool:
        st = self._swapped[req.rid]
        need = self.layout.pages_for(st.pos + self._n_meta)
        if self._free_capacity() < need + self.paged.reserve_pages:
            return False
        with self.tracer.span("sched.swap_in", rid=req.rid, pages=need):
            return self._swap_in(req, st, need)

    def _swap_in(self, req: Request, st: _SwapState, need: int) -> bool:
        pages = [self._page_or_none() for _ in range(need)]
        assert all(p is not None for p in pages)
        s = self._free.popleft()
        self._tables[s] = self.layout.scratch
        self._tables[s, :need] = pages
        self.pool = self._restore(self.pool, st.saved,
                                  jnp.asarray(self._tables[s]),
                                  jnp.asarray(s))
        self._queue.popleft()
        del self._swapped[req.rid]
        self._slot_rid[s] = req.rid
        self._slot_uses[s] += 1
        self._tok[s] = st.tok
        self._pos[s] = st.pos
        self._ngen[s] = st.ngen
        self._active[s] = True
        self._done[s] = False
        self._budget[s] = st.budget
        self._length[s] = st.length
        self._score[s] = st.score
        self._seed[s] = st.seed
        self.stats["swap_ins"] += 1
        self._note_pages()
        return True

    def _admit(self, now: float):
        # preempted requests sit at the queue front (swap state, no plan);
        # drain them before normal bucketed admission
        while (self._free and self._queue
               and self._queue[0].rid in self._swapped):
            if not self._try_swap_in(self._queue[0]):
                return                  # backpressure: keep FIFO order
        super()._admit(now)

    # -- decode: COW + page growth + preemption -----------------------------

    def _victim(self) -> Optional[int]:
        """Youngest-admitted live slot (LIFO preemption: the youngest
        request has done the least work and re-enters the queue FIRST of
        the preempted, preserving FIFO completion order overall)."""
        live = [s for s in range(self.n_slots)
                if self._slot_rid[s] is not None
                and self._active[s] and not self._done[s]]
        if not live:
            return None
        return max(live, key=lambda s: (
            self._meta[self._slot_rid[s]]["admitted_at"], s))

    def _preempt(self, s: int):
        rid = self._slot_rid[s]
        with self.tracer.span("sched.preempt.swap_out", rid=rid, slot=s):
            self._swap_out(s, rid)

    def _swap_out(self, s: int, rid: int):
        # the victim's own write-block may have been COW'd earlier in this
        # _ensure_writable pass — its table already points at the copy
        # destination, so the pending copy must execute before the gather
        # reads it
        self._flush_cow()
        # exceptional second host sync of the tick: swap-out must land in
        # host memory before its pages are recycled by the next alloc
        saved = jax.device_get(self._gather(
            self.pool, jnp.asarray(self._tables[s]), jnp.asarray(s)))
        self._swapped[rid] = _SwapState(
            tok=int(self._tok[s]), pos=int(self._pos[s]),
            ngen=int(self._ngen[s]), budget=int(self._budget[s]),
            length=int(self._length[s]), score=float(self._score[s]),
            seed=int(self._seed[s]), saved=saved)
        self._queue.appendleft(self._reqs[rid])
        self._release_slot_pages(s)
        self._slot_rid[s] = None
        self._active[s] = False
        self._done[s] = False
        self._free.append(s)
        self.stats["preemptions"] += 1

    def _grow_page(self, s: int) -> Optional[int]:
        """A page for slot ``s``'s next write — evicting prefix-cache
        entries, then preempting victims until one frees up. None means
        ``s`` itself was preempted (it was the last live slot)."""
        while True:
            p = self._page_or_none()
            if p is not None:
                return p
            v = self._victim()
            if v is None:
                raise PagePoolExhausted(
                    "no free pages and no live slot to preempt")
            self._preempt(v)
            if v == s:
                return None

    def _flush_cow(self):
        """Execute queued COW page copies, padded with scratch->scratch
        no-op pairs to a power-of-two width (bounded executable count).
        Gather-before-scatter semantics of `.at[dst].set(leaf[src])` make
        one batched call safe even when a freed source page was already
        handed back out as another pair's destination."""
        src, dst = self._cow_src, self._cow_dst
        if not src:
            return
        self._cow_src, self._cow_dst = [], []
        scratch = self.layout.scratch
        w = 1
        while w < len(src):
            w *= 2
        with self.tracer.span("sched.cow_flush", pairs=len(src), width=w):
            src = src + [scratch] * (w - len(src))
            dst = dst + [scratch] * (w - len(dst))
            self.pool = self._copy(self.pool,
                                   jnp.asarray(np.asarray(src, np.int32)),
                                   jnp.asarray(np.asarray(dst, np.int32)))

    def _ensure_writable(self, alive):
        """Pre-decode pass: every live slot's write-block must point at a
        private real page before the step writes K/V there."""
        ps, scratch = self.layout.page_size, self.layout.scratch
        self._cow_src, self._cow_dst = [], []
        for s in range(self.n_slots):
            if not alive[s] or self._slot_rid[s] is None:
                continue                # rid None: preempted this pass
            wb = (int(self._pos[s]) + self._n_meta) // ps
            page = int(self._tables[s, wb])
            if page == scratch:
                p = self._grow_page(s)
                if p is None:
                    continue
                self._tables[s, wb] = p
            elif self._pages.ref(page) > 1:
                p = self._grow_page(s)
                if p is None:
                    continue
                # the preemption inside _grow_page may itself have COW'd +
                # flushed; re-read the current page (still shared: only
                # OTHER slots' pages were released)
                self._cow_src.append(int(self._tables[s, wb]))
                self._cow_dst.append(p)
                self._pages.decref(int(self._tables[s, wb]))
                self._tables[s, wb] = p
                self.stats["cow_copies"] += 1
        self._flush_cow()
        self._note_pages()

    def _decode_call(self, alive):
        self._ensure_writable(alive)
        alive = self._active & ~self._done      # preemption may shrink it
        pool, nxt, lp = self._decode_fn(
            self.params, self.pool, jnp.asarray(self._tables),
            jnp.asarray(self._tok), jnp.asarray(self._pos),
            jnp.asarray(alive), self.rng, jnp.asarray(self._seed),
            jnp.asarray(self._ngen))
        self.pool = pool
        return nxt, lp

    def _retire(self, now: float) -> List[RequestResult]:
        retiring = [s for s in range(self.n_slots)
                    if self._slot_rid[s] is not None and self._done[s]]
        out = super()._retire(now)
        for s in retiring:
            self._release_slot_pages(s)
        return out


# ---------------------------------------------------------------------------
# static-batching baseline (table8's comparison point)
# ---------------------------------------------------------------------------

def static_batch_serve(params, cfg: ModelConfig, gen: GenerateConfig,
                       requests: Sequence[Request], *, batch_size: int,
                       ctx=None, rng: Optional[jax.Array] = None,
                       max_seq: Optional[int] = None
                       ) -> Tuple[Dict[int, np.ndarray], float]:
    """Pre-refactor serving shape: group requests FIFO into same-length
    batches of ``batch_size`` and run the one-shot engine batch by batch.
    Every batch runs until its slowest member finishes (max_new or all-
    EOS); per-request outputs are truncated to the request's budget —
    greedy decoding is prefix-stable, so truncation equals a shorter run.
    Returns ({rid: tokens}, wall_seconds)."""
    from repro.serve.engine import generate
    groups: Dict[Tuple[int, ...], List[Request]] = {}
    order: List[List[Request]] = []
    for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        key = (len(r.tokens),)
        g = groups.get(key)
        if g is None or len(g) >= batch_size:
            g = groups[key] = []
            order.append(g)
        g.append(r)
    g2 = dataclasses.replace(gen, max_seq=max_seq or gen.max_seq)
    out: Dict[int, np.ndarray] = {}
    t0 = monotonic()
    for g in order:
        batch = {"tokens": jnp.asarray(np.stack([r.tokens for r in g]))}
        for k in g[0].extras:
            batch[k] = jnp.asarray(np.stack([r.extras[k] for r in g]))
        # engine instances cache on (cfg, gen, ctx) + batch shape, so a
        # warmed-up trace replay pays zero compiles
        res = jax.block_until_ready(generate(params, batch, cfg, g2, ctx,
                                             rng))
        toks = np.asarray(res.tokens)
        lens = np.asarray(res.lengths)
        for i, r in enumerate(g):
            n = min(int(lens[i]), r.max_new or gen.max_new)
            out[r.rid] = toks[i, :n]
    return out, monotonic() - t0
