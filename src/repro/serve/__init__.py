from repro.serve.engine import (GenerateConfig, GenerateResult, generate,
                                make_generate_fn)

__all__ = ["GenerateConfig", "GenerateResult", "generate",
           "make_generate_fn"]
