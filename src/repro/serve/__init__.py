from repro.serve.engine import (GenerateConfig, GenerateResult,
                                decode_pool_step, generate, init_slot_pool,
                                make_generate_fn, prefill_into_slots,
                                slot_pool_like)
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   RequestResult, needs_exact_prefill,
                                   static_batch_serve)

__all__ = ["GenerateConfig", "GenerateResult", "generate",
           "make_generate_fn", "init_slot_pool", "slot_pool_like",
           "prefill_into_slots", "decode_pool_step", "ContinuousScheduler",
           "Request", "RequestResult", "needs_exact_prefill",
           "static_batch_serve"]
