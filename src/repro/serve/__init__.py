from repro.serve.engine import (GenerateConfig, GenerateResult,
                                decode_pool_step, generate, init_slot_pool,
                                make_generate_fn, prefill_into_slots,
                                slot_pool_like)
from repro.serve.paged import (PageAllocator, PagedLayout, PagePoolExhausted,
                               PrefixCache, decode_paged_step,
                               paged_kv_bytes, paged_pool_like,
                               prefill_into_pages)
from repro.serve.scheduler import (ContinuousScheduler, PagedScheduler,
                                   Request, RequestResult,
                                   needs_exact_prefill, static_batch_serve)

__all__ = ["GenerateConfig", "GenerateResult", "generate",
           "make_generate_fn", "init_slot_pool", "slot_pool_like",
           "prefill_into_slots", "decode_pool_step", "ContinuousScheduler",
           "PagedScheduler", "PagedLayout", "PageAllocator",
           "PagePoolExhausted", "PrefixCache", "paged_pool_like",
           "prefill_into_pages", "decode_paged_step", "paged_kv_bytes",
           "Request", "RequestResult", "needs_exact_prefill",
           "static_batch_serve"]
