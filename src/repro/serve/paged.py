"""Paged KV cache: page arena + block tables + host page allocator
(vLLM-style, DESIGN.md §13).

The slot pool (engine.init_slot_pool) reserves a full fixed-``max_seq``
cache row per request slot, so short requests strand most of their
reservation. This module replaces the SLOT axis of every full-length
attention-cache leaf with a PHYSICAL PAGE axis:

  slot pool  : (repeats, n_slots + 1, seq_len, ...)   one row per slot
  page arena : (repeats, n_pages + 1, page_size, ...) pages shared by all

A request's logical position p lives at arena slot ``[table[p // ps],
p % ps]`` where ``table`` is its (n_blocks,) block-table row, host-managed
by ``PageAllocator`` (refcounted — prefix sharing and copy-on-write need
pages with multiple owners). Arena index ``n_pages`` is a SCRATCH page:
dead slots' tables point every block at it, and prefill write-tables send
shared/beyond-prompt blocks there, so no extra masking plumbing exists —
scratch bytes are only ever read at positions the ``pos <= index``
predicate already masks to exact-zero probability.

Which leaves page is discovered STRUCTURALLY (`_cache_page_axes`), the
same eval_shape-diff trick as ``engine._cache_batch_axes``: leaves whose
shape tracks ``max_seq`` (full GQA KV, MLA latents) page; leaves that
don't (sliding-window rings + their ``pos`` leaf, SSM state, cross-KV)
keep the slot-pool layout — both layouts coexist in one cache pytree and
one decode executable.

Exactness: paged decode is BITWISE equal to slot-pool decode. Cache
writes happen BEFORE the attention read (write-then-attend), gathers are
copies, and every position past a row's depth — unwritten tail, scratch
bytes, a shared page's stale suffix — scores ``NEG_INF`` whose
``exp(NEG_INF - m)`` underflows to exactly 0.0, contributing exact-zero
terms to the same-shaped softmax reduction. ``tests/test_paged.py``
asserts the parity; ``benchmarks/table10_paged.py`` asserts it per
request on the table8 long-tail trace.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, init_cache, prefill
from repro.serve.engine import _cache_batch_axes


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# structural discovery: which cache leaves page
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _cache_page_axes(cfg: ModelConfig):
    """(batch_axes, seq_axes) leaf-aligned trees for the decode cache.

    ``seq_ax >= 0`` marks a PAGEABLE leaf (its shape tracks ``max_seq``);
    found by diffing ``init_cache`` leaf shapes at two cache lengths under
    ``eval_shape`` — ring buffers (sized by window), SSM state, cross-KV
    and the ring ``pos`` leaf don't move and stay slot-addressed. Pageable
    leaves are asserted to carry ``seq_ax == batch_ax + 1`` (the layout
    ``(repeats, batch, seq, ...)`` every attention cache family uses)."""
    a = jax.eval_shape(lambda: init_cache(cfg, 2, 16))
    b = jax.eval_shape(lambda: init_cache(cfg, 2, 24))

    def axis(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        assert len(diff) <= 1, (sa.shape, sb.shape)
        return diff[0] if diff else -1

    seq = jax.tree.map(axis, a, b)
    bat = _cache_batch_axes(cfg)
    jax.tree.map(lambda ab, as_: None if as_ < 0 else
                 (_ for _ in ()).throw(AssertionError((ab, as_)))
                 if not (ab >= 0 and as_ == ab + 1) else None, bat, seq)
    return bat, seq


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a page arena (hashable — part of jit cache keys).

    ``seq_len`` is the META-INCLUSIVE logical cache length (``max_seq +
    n_meta_tokens``); ``n_blocks = ceil(seq_len / page_size)`` is every
    block table's width. Arena leaves carry ``n_pages + 1`` pages — the
    last one (index ``n_pages``) is the shared scratch page."""
    page_size: int
    n_pages: int
    seq_len: int

    @property
    def n_blocks(self) -> int:
        return ceil_div(self.seq_len, self.page_size)

    @property
    def scratch(self) -> int:
        return self.n_pages

    def pages_for(self, n_positions: int) -> int:
        """Pages holding logical positions [0, n_positions)."""
        return ceil_div(n_positions, self.page_size)


def make_layout(cfg: ModelConfig, max_seq: int, page_size: int,
                n_pages: int) -> PagedLayout:
    n_meta = cfg.hybrid.n_meta_tokens if cfg.hybrid is not None else 0
    return PagedLayout(page_size=page_size, n_pages=n_pages,
                       seq_len=max_seq + n_meta)


# ---------------------------------------------------------------------------
# host-side page allocator (refcounted)
# ---------------------------------------------------------------------------

class PagePoolExhausted(RuntimeError):
    pass


class PageAllocator:
    """Free-list page allocator with per-page refcounts.

    ``alloc`` hands out the lowest-numbered free page (deterministic
    schedules => deterministic placement, which the parity benchmarks
    rely on for reproducibility); ``incref`` adds an owner (prefix-cache
    entry, sharing request); ``decref`` releases one and returns the page
    to the free list at refcount zero. Double-free and use-after-free are
    hard errors — ``tests/test_paged.py`` fuzzes these invariants."""

    def __init__(self, n_pages: int):
        assert n_pages >= 1
        self.n_pages = n_pages
        self._free = deque(range(n_pages))
        self._ref = np.zeros(n_pages, np.int64)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return int((self._ref > 0).sum())

    def ref(self, page: int) -> int:
        return int(self._ref[page])

    def try_alloc(self) -> Optional[int]:
        if not self._free:
            return None
        page = self._free.popleft()
        assert self._ref[page] == 0, (page, self._ref[page])
        self._ref[page] = 1
        return page

    def alloc(self) -> int:
        page = self.try_alloc()
        if page is None:
            raise PagePoolExhausted(
                f"all {self.n_pages} KV pages are referenced")
        return page

    def incref(self, page: int):
        if self._ref[page] <= 0:
            raise RuntimeError(f"incref on free page {page}")
        self._ref[page] += 1

    def decref(self, page: int):
        if self._ref[page] <= 0:
            raise RuntimeError(f"double free of page {page}")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)

    def check(self):
        """Conservation invariant: every page is free xor referenced."""
        assert (self._ref >= 0).all()
        held = int((self._ref > 0).sum())
        assert held + len(self._free) == self.n_pages, \
            (held, len(self._free), self.n_pages)
        assert len(set(self._free)) == len(self._free)


# ---------------------------------------------------------------------------
# token-hash prefix cache (host)
# ---------------------------------------------------------------------------

class PrefixCache:
    """LRU map from token-prefix keys to physical page lists.

    Two key families: ``("PG", f, prefix_bytes)`` — the first ``f`` FULL
    pages of a prompt whose page-covered token prefix hashes to
    ``prefix_bytes`` (page content at position p depends only on tokens
    <= p by causality, so equal prefixes => bitwise-equal pages); and
    ``("FULL", n, prompt_bytes)`` — a whole prompt including its partial
    tail page, so identical prompts share everything and the first
    divergent DECODE write triggers copy-on-write. The cache holds one
    refcount per page per entry; eviction (LRU, on allocation pressure)
    just decrefs — pages still owned by live requests survive until their
    last owner retires."""

    def __init__(self, alloc: PageAllocator):
        self._alloc = alloc
        self._entries: "OrderedDict[Tuple, List[int]]" = OrderedDict()
        self.hits = 0
        self.lookups = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key) -> Optional[List[int]]:
        pages = self._entries.get(key)
        if pages is not None:
            self._entries.move_to_end(key)
        return pages

    def put(self, key, pages: List[int]):
        if key in self._entries:
            return
        for p in pages:
            self._alloc.incref(p)
        self._entries[key] = list(pages)

    def evict_one(self) -> bool:
        """Drop the LRU entry; True if any entry was dropped."""
        if not self._entries:
            return False
        _, pages = self._entries.popitem(last=False)
        for p in pages:
            self._alloc.decref(p)
        return True

    def evictable_pages(self) -> int:
        """Pages that would return to the free list if every entry were
        evicted: referenced only by cache entries, not by any slot."""
        cref: Dict[int, int] = {}
        for pages in self._entries.values():
            for p in pages:
                cref[p] = cref.get(p, 0) + 1
        return sum(1 for p, c in cref.items() if self._alloc.ref(p) == c)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


# ---------------------------------------------------------------------------
# device-side paged pool primitives
# ---------------------------------------------------------------------------

def paged_pool_like(params, batch, cfg: ModelConfig, ctx=None, *,
                    max_seq: int, n_slots: int, layout: PagedLayout):
    """Paged decode pool shaped like the caches ``prefill`` will ACTUALLY
    produce for ``batch`` (cross-KV length follows the conditioning
    inputs, mirroring ``engine.slot_pool_like``). Pageable leaves become
    page arenas ``(..., n_pages + 1, page_size, ...)``; everything else
    keeps the slot-pool layout over ``n_slots`` rows (callers include the
    scratch slot). Shape-only (``eval_shape``): no compute."""
    _, fresh = jax.eval_shape(
        lambda p, b: prefill(p, b, cfg, ctx, max_seq=max_seq),
        params, batch)
    bat, seq = _cache_page_axes(cfg)

    def alloc(fr, ab, as_):
        if as_ >= 0:
            assert fr.shape[as_] == layout.seq_len, \
                (fr.shape, as_, layout.seq_len)
            shape = list(fr.shape)
            shape[ab] = layout.n_pages + 1
            shape[as_] = layout.page_size
            return jnp.zeros(tuple(shape), fr.dtype)
        if ab >= 0:
            shape = fr.shape[:ab] + (n_slots,) + fr.shape[ab + 1:]
        else:
            shape = fr.shape[:1] + (n_slots,) + fr.shape[1:]
        return jnp.zeros(shape, fr.dtype)

    return jax.tree.map(alloc, fresh, bat, seq)


def _put_slot_rows(pool_leaf, fresh_leaf, ax, slots):
    """engine._scatter_slots semantics for one slot-addressed leaf."""
    n = slots.shape[0]
    pool_ax = ax if ax >= 0 else 1
    if ax >= 0:
        rows = jnp.moveaxis(fresh_leaf, ax, 0).astype(pool_leaf.dtype)
    else:
        rows = jnp.broadcast_to(fresh_leaf.astype(pool_leaf.dtype),
                                (n,) + fresh_leaf.shape)
    out = jnp.moveaxis(pool_leaf, pool_ax, 0).at[slots].set(rows)
    return jnp.moveaxis(out, 0, pool_ax)


def scatter_pages(pool, fresh, cfg: ModelConfig, write_tables, slot_rows,
                  layout: PagedLayout):
    """Write per-request prefill caches into the paged pool.

    ``write_tables`` (W, n_blocks) int32 routes each request's logical
    block to its DESTINATION page — entries pointing at the scratch page
    skip the write in effect (shared prefix pages whose content already
    exists, blocks past the request's allocation, dummy admission rows).
    ``slot_rows`` (W,) routes the slot-addressed leaves exactly as
    ``engine._scatter_slots`` does (scratch slot for dummies)."""
    bat, seq = _cache_page_axes(cfg)
    ps, nb = layout.page_size, layout.n_blocks
    w = write_tables.shape[0]
    flat = write_tables.reshape(-1)

    def put(pool_leaf, fr, ab, as_):
        if as_ < 0:
            return _put_slot_rows(pool_leaf, fr, ab, slot_rows)
        assert ab == 1 and as_ == 2, (ab, as_)
        rep = fr.shape[0]
        rest = fr.shape[3:]
        pad = nb * ps - fr.shape[2]
        f = jnp.pad(fr, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * len(rest)) \
            if pad else fr
        f = f.reshape((rep, w, nb, ps) + rest)
        f = jnp.moveaxis(f, (1, 2), (0, 1))          # (W, nb, rep, ps, ...)
        f = f.reshape((w * nb, rep, ps) + rest).astype(pool_leaf.dtype)
        arena = jnp.moveaxis(pool_leaf, 1, 0).at[flat].set(f)
        return jnp.moveaxis(arena, 0, 1)

    return jax.tree.map(put, pool, fresh, bat, seq)


def prefill_into_pages(params, batch: Dict[str, Any], lengths: jax.Array,
                       write_tables: jax.Array, slot_rows: jax.Array, pool,
                       cfg: ModelConfig, ctx=None, *, max_seq: int,
                       layout: PagedLayout,
                       rng: Optional[jax.Array] = None):
    """Prefill a group of new requests into their allocated pages.

    The full prompt is always COMPUTED (prefix caching saves cache
    MEMORY, not prefill FLOPs — a shared page is simply not re-written,
    keeping the cached bytes pristine for its other owners); the
    write-table decides which produced blocks land in the arena. Returns
    ``(logits (W, V) at each row's last real token, pool')``."""
    logits, fresh = prefill(params, batch, cfg, ctx, max_seq=max_seq,
                            rng=rng, last_index=lengths - 1)
    pool = scatter_pages(pool, fresh, cfg, write_tables, slot_rows, layout)
    return logits[:, 0], pool


def decode_paged_step(params, pool, block_tables: jax.Array,
                      tok: jax.Array, pos: jax.Array, alive: jax.Array,
                      cfg: ModelConfig, ctx=None, *,
                      local_routing: bool = False,
                      flash_decode: bool = False):
    """One batched paged ``decode_step`` over all S block-table rows at
    per-row positions — the paged twin of ``engine.decode_pool_step`` and
    the ONE decode executable of a paged serving process."""
    lg, pool = decode_step(params, pool, tok[:, None], pos, cfg, ctx,
                           local_routing=local_routing, token_valid=alive,
                           flash_decode=flash_decode,
                           block_tables=block_tables)
    return lg[:, 0], pool


def copy_pages(pool, cfg: ModelConfig, src: jax.Array, dst: jax.Array):
    """Copy-on-write: duplicate arena pages ``src[i] -> dst[i]`` on every
    pageable leaf (a page copy IS bitwise — the COW'd owner keeps exactly
    the bytes it would have had unshared). Callers pad ``src``/``dst``
    with scratch->scratch pairs to a fixed width so the executable count
    stays bounded."""
    bat, seq = _cache_page_axes(cfg)

    def cp(leaf, ab, as_):
        del ab
        if as_ < 0:
            return leaf
        return leaf.at[:, dst].set(leaf[:, src])

    return jax.tree.map(cp, pool, bat, seq)


def gather_slot_state(pool, cfg: ModelConfig, table_row: jax.Array,
                      slot: jax.Array):
    """Swap-out reads (preemption): a slot's pages gathered page-major
    ``(repeats, n_blocks, page_size, ...)`` plus its slot-addressed leaf
    rows. jax arrays are immutable, so the gather is consistent even
    though the host frees the pages immediately after."""
    bat, seq = _cache_page_axes(cfg)

    def g(leaf, ab, as_):
        if as_ >= 0:
            return leaf[:, table_row]
        pool_ax = ab if ab >= 0 else 1
        return jnp.take(leaf, slot, axis=pool_ax)

    return jax.tree.map(g, pool, bat, seq)


def restore_slot_state(pool, cfg: ModelConfig, saved, table_row: jax.Array,
                       slot: jax.Array):
    """Swap-in writes: the inverse of ``gather_slot_state`` against a
    FRESH page allocation (``table_row``). Values round-trip bitwise —
    preemption via swap preserves per-request output parity, which
    recompute-style preemption could not guarantee."""
    bat, seq = _cache_page_axes(cfg)

    def r(leaf, sv, ab, as_):
        sv = jnp.asarray(sv, leaf.dtype)
        if as_ >= 0:
            arena = jnp.moveaxis(leaf, 1, 0)
            rows = jnp.moveaxis(sv, 1, 0)            # (nb, rep, ps, ...)
            return jnp.moveaxis(arena.at[table_row].set(rows), 0, 1)
        pool_ax = ab if ab >= 0 else 1
        m = jnp.moveaxis(leaf, pool_ax, 0)
        return jnp.moveaxis(m.at[slot].set(sv), 0, pool_ax)

    return jax.tree.map(r, pool, saved, bat, seq)


def paged_kv_bytes(pool, cfg: ModelConfig) -> int:
    """Total bytes of the PAGEABLE leaves of ``pool`` (the memory the
    page arena actually pins — the --trace cache section reports this)."""
    bat, seq = _cache_page_axes(cfg)
    leaves = jax.tree.leaves(jax.tree.map(
        lambda leaf, ab, as_: leaf.size * leaf.dtype.itemsize
        if as_ >= 0 else 0, pool, bat, seq))
    return int(sum(leaves))
