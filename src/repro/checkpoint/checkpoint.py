"""Sharded-pytree checkpointing to .npz (no external deps).

Layout:  <dir>/step_<n>/arrays.npz + meta.json, plus <dir>/latest file
pointing at the most recent step. Keys are '/'-joined tree paths, so a
checkpoint restores into any pytree with the same structure.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """numpy has no bfloat16: such leaves are stored as uint16 bit patterns
    with the true dtype recorded in meta."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaf = jax.device_get(leaf)
        if leaf.dtype == jax.numpy.bfloat16:
            dtypes[key] = "bfloat16"
            flat[key] = np.asarray(leaf.view(jax.numpy.uint16))
        else:
            flat[key] = np.asarray(leaf)
    return flat, dtypes


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra_meta: Optional[Dict] = None) -> str:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, dtypes = _flatten(tree)
    np.savez(os.path.join(d, "arrays.npz"), **flat)
    meta = {"step": step, "n_arrays": len(flat), "dtypes": dtypes}
    if extra_meta:
        meta.update(extra_meta)
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(ckpt_dir, "latest"), "w") as f:
        f.write(f"step_{step:08d}")
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip().split("_")[-1])


def restore_checkpoint(ckpt_dir: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into ``template``'s structure (shapes/dtypes must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        if meta.get("dtypes", {}).get(key) == "bfloat16":
            val = jax.numpy.asarray(arr).view(jax.numpy.bfloat16)
        else:
            val = jax.numpy.asarray(arr)
        leaves.append(val.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
