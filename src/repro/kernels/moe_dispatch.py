"""MoE token dispatch / combine Pallas kernels (scalar-prefetch gathers).

TPU adaptation of the scatter/gather around the all-to-all: instead of a
data-dependent scatter (expensive on TPU), routing is precomputed into a
slot->token map and the kernels become PURE GATHERS whose BlockSpec
index_maps read the prefetched scalar routing tables — each grid step DMAs
exactly one (1, d)-row from HBM to VMEM. This is the megablocks-style
TPU-idiomatic form: the MXU never sees routing logic, and the gather rides
the scalar-prefetch pipeline.

  dispatch: buf[s] = x[slot_token[s]] * valid[s]       (S = E*C slots)
  combine : y[t]  = sum_k w[t,k] * buf[token_slot[t,k]]

``interpret=None`` (default) auto-detects the platform (DESIGN.md §6):
compiled on TPU, interpreter elsewhere. The slot maps consumed here are
built once per step by ``repro.kernels.ops.routing_tables`` and shared by
both gathers.

Both ops are linear in their float inputs, so they carry custom VJPs whose
backwards are plain jnp scatter/gather (the transpose of a gather) — the
pallas backend is differentiable end-to-end inside the train step even
where Pallas itself cannot JVP through scalar-prefetch calls.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import fit_block, resolve_interpret


def _float0_like(a: jax.Array):
    """Zero cotangent for an integer/bool primal (custom_vjp contract)."""
    return np.zeros(np.shape(a), jax.dtypes.float0)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def _dispatch_kernel(idx_ref, valid_ref, x_ref, o_ref):
    s = pl.program_id(0)
    o_ref[0] = jnp.where(valid_ref[s] > 0, x_ref[0], 0).astype(o_ref.dtype)


def _dispatch_impl(x, idx, valid, bd, interpret):
    t, d = x.shape
    s = idx.shape[0]
    bd = fit_block(d, bd)
    grid = (s, d // bd)
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bd), lambda si, dj, idx, val: (idx[si], dj)),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda si, dj, idx, val: (si, dj)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=interpret,
    )(idx, valid, x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _dispatch(x, idx, valid, bd, interpret):
    return _dispatch_impl(x, idx, valid, bd, interpret)


def _dispatch_fwd(x, idx, valid, bd, interpret):
    # zero-byte probe keeps x's (T, dtype) in the residuals as a JAX type
    # (raw shape/dtype objects would break scan-of-layers transposition)
    probe = jnp.zeros((x.shape[0], 0), x.dtype)
    return _dispatch_impl(x, idx, valid, bd, interpret), (idx, valid, probe)


def _dispatch_bwd(bd, interpret, res, dy):
    idx, valid, probe = res
    # transpose of the gather: scatter-add rows back onto their tokens
    dy = jnp.where(valid[:, None], dy.astype(jnp.float32), 0)
    dx = jnp.zeros((probe.shape[0], dy.shape[1]), jnp.float32).at[idx].add(dy)
    return dx.astype(probe.dtype), _float0_like(idx), _float0_like(valid)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def _dispatch_jit(x, slot_token, slot_valid, bd, interpret):
    idx = jnp.clip(slot_token, 0, x.shape[0] - 1).astype(jnp.int32)
    valid = slot_valid.astype(jnp.int32)
    return _dispatch(x, idx, valid, bd, interpret)


def dispatch(x: jax.Array, slot_token: jax.Array, slot_valid: jax.Array, *,
             bd: int = 512, interpret: Optional[bool] = None) -> jax.Array:
    """x: (T, d); slot_token/slot_valid: (S,). Returns (S, d) buffer rows.

    interpret resolves BEFORE the jit boundary so the cached executable is
    keyed on the concrete mode (force_interpret stays effective)."""
    return _dispatch_jit(x, slot_token, slot_valid, bd,
                         resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# combine
# ---------------------------------------------------------------------------

def _make_combine_kernel(k: int):
    def kernel(slot_ref, w_ref, *refs):
        # refs: k buffer views + o_ref
        o_ref = refs[-1]
        t = pl.program_id(0)
        acc = jnp.zeros(o_ref.shape[-1:], jnp.float32)
        for kk in range(k):
            acc = acc + w_ref[t, kk] * refs[kk][0].astype(jnp.float32)
        o_ref[0] = acc.astype(o_ref.dtype)
    return kernel


def _combine_impl(buf, slots, w, bd, interpret):
    s, d = buf.shape
    t, k = slots.shape
    bd = fit_block(d, bd)
    grid = (t, d // bd)
    in_specs = [
        pl.BlockSpec((1, bd),
                     functools.partial(
                         lambda kk, ti, dj, slot, w_: (slot[ti, kk], dj), kk))
        for kk in range(k)
    ]
    return pl.pallas_call(
        _make_combine_kernel(k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bd), lambda ti, dj, slot, w_: (ti, dj)),
        ),
        out_shape=jax.ShapeDtypeStruct((t, d), buf.dtype),
        interpret=interpret,
    )(slots, w, *([buf] * k))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _combine(buf, slots, w, bd, interpret):
    return _combine_impl(buf, slots, w, bd, interpret)


def _combine_fwd(buf, slots, w, bd, interpret):
    return _combine_impl(buf, slots, w, bd, interpret), (buf, slots, w)


def _combine_bwd(bd, interpret, res, dy):
    buf, slots, w = res
    t, k = slots.shape
    dyf = dy.astype(jnp.float32)
    # dbuf[s] = sum_{(t,k)->s} w[t,k] * dy[t]   (transpose of the gather)
    contrib = (w[..., None] * dyf[:, None, :]).reshape(t * k, -1)
    dbuf = jnp.zeros(buf.shape, jnp.float32).at[slots.reshape(-1)].add(contrib)
    # dw[t,k] = <dy[t], buf[slots[t,k]]>
    rows = jnp.take(buf, slots.reshape(-1), axis=0).reshape(t, k, -1)
    dw = jnp.einsum("td,tkd->tk", dyf, rows.astype(jnp.float32))
    return dbuf.astype(buf.dtype), _float0_like(slots), dw.astype(w.dtype)


_combine.defvjp(_combine_fwd, _combine_bwd)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def _combine_jit(buf, token_slot, weights, keep, bd, interpret):
    slots = jnp.clip(token_slot, 0, buf.shape[0] - 1).astype(jnp.int32)
    w = (weights * keep).astype(jnp.float32)   # grads reach weights here
    return _combine(buf, slots, w, bd, interpret)


def combine(buf: jax.Array, token_slot: jax.Array, weights: jax.Array,
            keep: jax.Array, *, bd: int = 512,
            interpret: Optional[bool] = None) -> jax.Array:
    """buf: (S, d); token_slot: (T, K); weights/keep: (T, K) -> y (T, d)."""
    return _combine_jit(buf, token_slot, weights, keep, bd,
                        resolve_interpret(interpret))
