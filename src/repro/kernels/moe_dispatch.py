"""MoE token dispatch / combine Pallas kernels (scalar-prefetch gathers).

TPU adaptation of the scatter/gather around the all-to-all: instead of a
data-dependent scatter (expensive on TPU), routing is precomputed into a
slot->token map and the kernels become PURE GATHERS whose BlockSpec
index_maps read the prefetched scalar routing tables — each grid step DMAs
exactly one (1, d)-row from HBM to VMEM. This is the megablocks-style
TPU-idiomatic form: the MXU never sees routing logic, and the gather rides
the scalar-prefetch pipeline.

  dispatch: buf[s] = x[slot_token[s]] * valid[s]       (S = E*C slots)
  combine : y[t]  = sum_k w[t,k] * buf[token_slot[t,k]]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def _dispatch_kernel(idx_ref, valid_ref, x_ref, o_ref):
    s = pl.program_id(0)
    o_ref[0] = jnp.where(valid_ref[s] > 0, x_ref[0], 0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def dispatch(x: jax.Array, slot_token: jax.Array, slot_valid: jax.Array, *,
             bd: int = 512, interpret: bool = True) -> jax.Array:
    """x: (T, d); slot_token/slot_valid: (S,). Returns (S, d) buffer rows."""
    t, d = x.shape
    s = slot_token.shape[0]
    bd = min(bd, d)
    assert d % bd == 0
    idx = jnp.clip(slot_token, 0, t - 1).astype(jnp.int32)
    valid = slot_valid.astype(jnp.int32)
    grid = (s, d // bd)
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bd), lambda si, dj, idx, val: (idx[si], dj)),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda si, dj, idx, val: (si, dj)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=interpret,
    )(idx, valid, x)


# ---------------------------------------------------------------------------
# combine
# ---------------------------------------------------------------------------

def _make_combine_kernel(k: int):
    def kernel(slot_ref, w_ref, *refs):
        # refs: k buffer views + o_ref
        o_ref = refs[-1]
        t = pl.program_id(0)
        acc = jnp.zeros(o_ref.shape[-1:], jnp.float32)
        for kk in range(k):
            acc = acc + w_ref[t, kk] * refs[kk][0].astype(jnp.float32)
        o_ref[0] = acc.astype(o_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def combine(buf: jax.Array, token_slot: jax.Array, weights: jax.Array,
            keep: jax.Array, *, bd: int = 512,
            interpret: bool = True) -> jax.Array:
    """buf: (S, d); token_slot: (T, K); weights/keep: (T, K) -> y (T, d)."""
    s, d = buf.shape
    t, k = token_slot.shape
    bd = min(bd, d)
    assert d % bd == 0
    slots = jnp.clip(token_slot, 0, s - 1).astype(jnp.int32)
    w = (weights * keep).astype(jnp.float32)
    grid = (t, d // bd)
    in_specs = [
        pl.BlockSpec((1, bd),
                     functools.partial(
                         lambda kk, ti, dj, slot, w_: (slot[ti, kk], dj), kk))
        for kk in range(k)
    ]
    return pl.pallas_call(
        _make_combine_kernel(k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bd), lambda ti, dj, slot, w_: (ti, dj)),
        ),
        out_shape=jax.ShapeDtypeStruct((t, d), buf.dtype),
        interpret=interpret,
    )(slots, w, *([buf] * k))
