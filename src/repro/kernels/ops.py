"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU container); on real TPU pass
interpret=False (the kernels are written with MXU-aligned BlockSpecs).
Routing-table construction (slot maps) lives here: it turns the
router's DispatchInfo into the gather form the kernels consume.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import contextlib

from repro.core.router import DispatchInfo
from repro.kernels.flash_decode import flash_decode
from repro.kernels.grouped_ffn import grouped_matmul
from repro.kernels.moe_dispatch import combine, dispatch

# Global switch: when True the MoE layer routes its dispatch/FFN/combine
# through the Pallas kernels (interpret=True on CPU). Flip with use_kernels().
KERNELS_ENABLED = False


@contextlib.contextmanager
def use_kernels(enabled: bool = True):
    global KERNELS_ENABLED
    prev = KERNELS_ENABLED
    KERNELS_ENABLED = enabled
    try:
        yield
    finally:
        KERNELS_ENABLED = prev


def build_slot_maps(info: DispatchInfo, n_experts: int,
                    cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """DispatchInfo -> (slot_token (E*C,), slot_valid (E*C,), token_slot (T,K)).

    slot_token[e*C + c] = which token fills slot c of expert e;
    token_slot[t, k]    = flat slot index for the (t, k) routing choice.
    """
    t, k = info.topk_idx.shape
    flat_e = info.topk_idx.reshape(-1)
    flat_p = info.pos.reshape(-1)
    keep = info.keep.reshape(-1)
    flat_slot = jnp.where(keep, flat_e * cap + flat_p, n_experts * cap)
    token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    slot_token = jnp.full((n_experts * cap + 1,), -1, jnp.int32
                          ).at[flat_slot].set(token_ids, mode="drop")[:-1]
    slot_valid = slot_token >= 0
    token_slot = jnp.where(keep, flat_e * cap + flat_p, 0).reshape(t, k)
    return slot_token, slot_valid, token_slot


def moe_dispatch_op(x: jax.Array, info: DispatchInfo, n_experts: int,
                    cap: int, *, interpret: bool = True) -> jax.Array:
    """Kernel-backed equivalent of router.dispatch: (T,d) -> (E, C, d)."""
    slot_token, slot_valid, _ = build_slot_maps(info, n_experts, cap)
    buf = dispatch(x, slot_token, slot_valid, interpret=interpret)
    return buf.reshape(n_experts, cap, x.shape[-1])


def moe_combine_op(buf: jax.Array, info: DispatchInfo, *,
                   interpret: bool = True) -> jax.Array:
    """Kernel-backed equivalent of router.combine: (E, C, d) -> (T, d)."""
    e, cap, d = buf.shape
    _, _, token_slot = build_slot_maps(info, e, cap)
    return combine(buf.reshape(e * cap, d), token_slot, info.topk_w,
                   info.keep, interpret=interpret)


def expert_ffn_op(buf: jax.Array, w_in: jax.Array, w_gate, w_out: jax.Array,
                  act: str = "silu", *, interpret: bool = True) -> jax.Array:
    """Full gated expert FFN from grouped_matmul kernels."""
    h = grouped_matmul(buf, w_in, interpret=interpret)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    if w_gate is not None:
        g = grouped_matmul(buf, w_gate, interpret=interpret)
        h = actf(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = actf(h.astype(jnp.float32)).astype(h.dtype)
    return grouped_matmul(h, w_out, interpret=interpret)


__all__ = ["build_slot_maps", "combine", "dispatch", "expert_ffn_op",
           "flash_decode", "grouped_matmul", "moe_combine_op",
           "moe_dispatch_op"]
