"""Jit'd public wrappers around the Pallas kernels.

``interpret=None`` (the default) auto-detects the platform (DESIGN.md §6):
kernels compile on TPU and run under the Pallas interpreter elsewhere.
Routing-table construction (slot maps) lives here: ``routing_tables`` turns
the router's DispatchInfo into the gather form the kernels consume, ONCE
per step — both the dispatch and the combine gather reuse the same tables.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import contextlib

from repro.core.router import DispatchInfo
from repro.kernels.flash_decode import flash_decode
from repro.kernels.grouped_ffn import grouped_matmul
from repro.kernels.moe_dispatch import combine, dispatch
from repro.kernels.moe_megakernel import fused_moe_ffn
from repro.kernels.platform import (default_interpret, force_interpret,
                                    resolve_interpret)

# Global switch: when True the MoE layer routes its dispatch/FFN/combine
# through the Pallas kernels (interpret mode off-TPU). Flip with
# use_kernels(); the `pallas` execution backend (core/backend.py) uses the
# kernels unconditionally.
KERNELS_ENABLED = False


@contextlib.contextmanager
def use_kernels(enabled: bool = True):
    global KERNELS_ENABLED
    prev = KERNELS_ENABLED
    KERNELS_ENABLED = enabled
    try:
        yield
    finally:
        KERNELS_ENABLED = prev


class RoutingTables(NamedTuple):
    """Gather-form routing state, built once per step from DispatchInfo.

    slot_token[e*C + c] = which token fills slot c of expert e (-1 empty);
    slot_valid[s]       = slot s is occupied;
    token_slot[t, k]    = flat slot index for the (t, k) routing choice.
    """
    slot_token: jax.Array    # (E*C,) int32
    slot_valid: jax.Array    # (E*C,) bool
    token_slot: jax.Array    # (T, K) int32


def routing_tables(info: DispatchInfo, n_experts: int,
                   cap: int) -> RoutingTables:
    """DispatchInfo -> RoutingTables. The fused builder: one scatter over
    (T*k,) produces both gather maps, so dispatch and combine share it."""
    t, k = info.topk_idx.shape
    flat_e = info.topk_idx.reshape(-1)
    flat_p = info.pos.reshape(-1)
    keep = info.keep.reshape(-1)
    flat_slot = jnp.where(keep, flat_e * cap + flat_p, n_experts * cap)
    token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    slot_token = jnp.full((n_experts * cap + 1,), -1, jnp.int32
                          ).at[flat_slot].set(token_ids, mode="drop")[:-1]
    slot_valid = slot_token >= 0
    token_slot = jnp.where(keep, flat_e * cap + flat_p, 0).reshape(t, k)
    return RoutingTables(slot_token, slot_valid, token_slot)


def build_slot_maps(info: DispatchInfo, n_experts: int,
                    cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Back-compat alias of routing_tables (returns the same named tuple)."""
    return routing_tables(info, n_experts, cap)


def moe_dispatch_op(x: jax.Array, info: DispatchInfo, n_experts: int,
                    cap: int, *, interpret: Optional[bool] = None,
                    tables: Optional[RoutingTables] = None) -> jax.Array:
    """Kernel-backed equivalent of router.dispatch: (T,d) -> (E, C, d).

    Pass ``tables`` (from routing_tables) to reuse slot maps already built
    for this step instead of recomputing them."""
    if tables is None:
        tables = routing_tables(info, n_experts, cap)
    buf = dispatch(x, tables.slot_token, tables.slot_valid,
                   interpret=interpret)
    return buf.reshape(n_experts, cap, x.shape[-1])


def moe_combine_op(buf: jax.Array, info: DispatchInfo, *,
                   interpret: Optional[bool] = None,
                   tables: Optional[RoutingTables] = None) -> jax.Array:
    """Kernel-backed equivalent of router.combine: (E, C, d) -> (T, d)."""
    e, cap, d = buf.shape
    if tables is None:
        tables = routing_tables(info, e, cap)
    return combine(buf.reshape(e * cap, d), tables.token_slot, info.topk_w,
                   info.keep, interpret=interpret)


def fused_moe_op(x: jax.Array, info: DispatchInfo, w_in: jax.Array, w_gate,
                 w_out: jax.Array, n_experts: int, cap: int,
                 act: str = "silu", *, interpret: Optional[bool] = None,
                 tables: Optional[RoutingTables] = None) -> jax.Array:
    """ONE-launch fused equivalent of dispatch -> expert_ffn_op -> combine
    (kernels.moe_megakernel, DESIGN.md §11): (T, d) -> (T, d) without ever
    materializing the (E, C, d) buffer in HBM. ``tables`` drive the
    in-kernel gather/scatter and the custom VJP's slot-formulation
    backward."""
    if tables is None:
        tables = routing_tables(info, n_experts, cap)
    return fused_moe_ffn(x, w_in, w_gate, w_out, info.topk_w,
                         info.keep, tables.slot_token, tables.slot_valid,
                         tables.token_slot, act=act, interpret=interpret)


def expert_ffn_op(buf: jax.Array, w_in: jax.Array, w_gate, w_out: jax.Array,
                  act: str = "silu", *,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Full gated expert FFN from grouped_matmul kernels."""
    h = grouped_matmul(buf, w_in, interpret=interpret)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    if w_gate is not None:
        g = grouped_matmul(buf, w_gate, interpret=interpret)
        h = actf(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = actf(h.astype(jnp.float32)).astype(h.dtype)
    return grouped_matmul(h, w_out, interpret=interpret)


__all__ = ["RoutingTables", "build_slot_maps", "combine", "default_interpret",
           "dispatch", "expert_ffn_op", "flash_decode", "force_interpret",
           "fused_moe_ffn", "fused_moe_op", "grouped_matmul",
           "moe_combine_op", "moe_dispatch_op", "resolve_interpret",
           "routing_tables"]
