"""Grouped (per-expert) matmul Pallas kernel — the MoE compute hot spot.

TPU adaptation: after the all-to-all, every device holds (E_local, C, d)
token buffers and (E_local, d, f) expert weights. A naive einsum pays one
XLA loop per expert; this kernel tiles (C, f) blocks per expert on the
MXU with an f32 VMEM accumulator, block shapes multiples of 128 on the
minor dims.

Grid: (E, C/bc, F/bf, D/bd) — innermost axis accumulates over d.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import fit_block, resolve_interpret


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _gmm_impl(x, w, bc, bf, bd, interpret):
    e, c, d = x.shape
    _, _, f = w.shape
    bc = fit_block(c, bc)
    bf = fit_block(f, bf)
    bd = fit_block(d, bd)
    grid = (e, c // bc, f // bf, d // bd)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _gmm(x, w, bc, bf, bd, interpret):
    return _gmm_impl(x, w, bc, bf, bd, interpret)


def _gmm_fwd(x, w, bc, bf, bd, interpret):
    return _gmm_impl(x, w, bc, bf, bd, interpret), (x, w)


def _gmm_bwd(bc, bf, bd, interpret, res, dy):
    # both cotangents are themselves grouped matmuls — reuse the kernel
    x, w = res
    dx = _gmm_impl(dy, jnp.swapaxes(w, 1, 2), bc, bd, bf, interpret)
    dw = _gmm_impl(jnp.swapaxes(x, 1, 2), dy, bd, bf, bc, interpret)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_gmm.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def _gmm_jit(x, w, bc, bf, bd, interpret):
    return _gmm(x, w, bc, bf, bd, interpret)


def grouped_matmul(x: jax.Array, w: jax.Array, *, bc: int = 128,
                   bf: int = 128, bd: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """x: (E, C, d) @ w: (E, d, f) -> (E, C, f), per-expert.

    interpret=None auto-detects the platform (resolved before the jit
    boundary so the cache is keyed on the concrete mode); block sizes
    shrink to exact divisors on non-MXU-aligned (test) shapes.
    Differentiable via a custom VJP whose backward runs the same kernel on
    transposed operands."""
    return _gmm_jit(x, w, bc, bf, bd, resolve_interpret(interpret))
