"""Grouped (per-expert) matmul Pallas kernel — the MoE compute hot spot.

TPU adaptation: after the all-to-all, every device holds (E_local, C, d)
token buffers and (E_local, d, f) expert weights. A naive einsum pays one
XLA loop per expert; this kernel tiles (C, f) blocks per expert on the
MXU with an f32 VMEM accumulator, block shapes multiples of 128 on the
minor dims.

Grid: (E, C/bc, F/bf, D/bd) — innermost axis accumulates over d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, *, bc: int = 128,
                   bf: int = 128, bd: int = 128,
                   interpret: bool = True) -> jax.Array:
    """x: (E, C, d) @ w: (E, d, f) -> (E, C, f), per-expert."""
    e, c, d = x.shape
    _, _, f = w.shape
    bc = min(bc, c)
    bf = min(bf, f)
    bd = min(bd, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (x.shape, w.shape)
    grid = (e, c // bc, f // bf, d // bd)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
