"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (E, C, d), w: (E, d, f) -> (E, C, f). f32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def dispatch_ref(x: jax.Array, slot_token: jax.Array,
                 slot_valid: jax.Array) -> jax.Array:
    """Gather-form token dispatch.

    x: (T, d); slot_token: (S,) int32 token index feeding each expert-buffer
    slot (row-major (E, C) flattened); slot_valid: (S,) bool.
    Returns (S, d) expert buffer rows.
    """
    rows = jnp.take(x, jnp.clip(slot_token, 0, x.shape[0] - 1), axis=0)
    return jnp.where(slot_valid[:, None], rows, 0).astype(x.dtype)


def combine_ref(buf: jax.Array, token_slot: jax.Array, weights: jax.Array,
                keep: jax.Array) -> jax.Array:
    """Weighted gather-combine of expert outputs.

    buf: (S, d) flattened expert buffer rows; token_slot: (T, K) int32 slot
    per (token, k); weights: (T, K) f32; keep: (T, K) bool.
    Returns (T, d).
    """
    g = jnp.take(buf, jnp.clip(token_slot, 0, buf.shape[0] - 1), axis=0)
    w = (weights * keep).astype(jnp.float32)
    return jnp.einsum("tkd,tk->td", g.astype(jnp.float32), w).astype(buf.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     index: jax.Array) -> jax.Array:
    """Single-token decode attention.

    q: (B, H, hd); k, v: (B, S, KV, hd); index: scalar or (B,) — positions
    > index (per row) masked out. Returns (B, H, hd).
    """
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    rep = h // kv
    ke = jnp.repeat(k, rep, axis=2)
    ve = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        ke.astype(jnp.float32)) * (hd ** -0.5)
    idx = jnp.broadcast_to(jnp.asarray(index).reshape(-1), (b,))
    valid = jnp.arange(s)[None, :] <= idx[:, None]             # (B, S)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p,
                      ve.astype(jnp.float32)).astype(q.dtype)
