"""Flash decode-attention Pallas kernel: one query token vs a long KV cache.

The decode shapes (decode_32k, long_500k) are memory-bound: the whole KV
cache streams HBM->VMEM once per step. Grid (B, KV, S/bs) walks KV blocks
with a running online-softmax (m, l, acc) in VMEM scratch; the GQA group's
`rep` query heads share each KV block read (the factor that makes GQA
decode HBM-efficient). Block sizes are multiples of 128 on the minor dim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import fit_block, resolve_interpret

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bs: int, scale: float):
    bi = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (rep, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (rep, bs)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos <= idx_ref[bi], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))     # (rep, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def _flash_decode_jit(q, k, v, index, bs, interpret):
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    rep = h // kv
    bs = fit_block(s, bs)
    qg = q.reshape(b, kv, rep, hd)
    grid = (b, kv, s // bs)
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, hd), lambda bi, g, j, idx: (bi, g, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd), lambda bi, g, j, idx: (bi, j, g, 0)),
                pl.BlockSpec((1, bs, 1, hd), lambda bi, g, j, idx: (bi, j, g, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, hd),
                                   lambda bi, g, j, idx: (bi, g, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
        interpret=interpret,
    )(jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (b,)),
      qg, k, v)
    return out.reshape(b, h, hd)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, index: jax.Array,
                 *, bs: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, H, hd); k, v: (B, S, KV, hd); index: scalar int32 OR (B,) —
    positions > index (per row) are masked; the per-row form serves the
    slot-pool decode path where every request sits at its own depth
    (DESIGN.md §9). Returns (B, H, hd). interpret=None -> platform
    (resolved before the jit boundary so the cached executable is keyed on
    the concrete mode)."""
    return _flash_decode_jit(q, k, v, index, bs, resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# paged variant: block-table gather in the kernel prologue (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _paged_kernel(idx_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, ps: int, scale: float):
    # bt_ref is consumed by the BlockSpec index maps: grid step (b, g, j)
    # DMAs physical page bt[b, j] of the arena into VMEM, so the kernel
    # body is the plain online-softmax update over one page — logical
    # position j*ps + i maps 1:1 onto the slot-row kernel's j*bs + i.
    del bt_ref
    _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            bs=ps, scale=scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _flash_decode_paged_jit(q, k, v, block_tables, index, interpret):
    b, h, hd = q.shape
    ps, kv = k.shape[1], k.shape[2]
    nb = block_tables.shape[1]
    rep = h // kv
    qg = q.reshape(b, kv, rep, hd)
    grid = (b, kv, nb)
    out = pl.pallas_call(
        functools.partial(_paged_kernel, ps=ps, scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, hd),
                             lambda bi, g, j, idx, bt: (bi, g, 0, 0)),
                pl.BlockSpec((1, ps, 1, hd),
                             lambda bi, g, j, idx, bt: (bt[bi, j], 0, g, 0)),
                pl.BlockSpec((1, ps, 1, hd),
                             lambda bi, g, j, idx, bt: (bt[bi, j], 0, g, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, hd),
                                   lambda bi, g, j, idx, bt: (bi, g, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
        interpret=interpret,
    )(jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (b,)),
      jnp.asarray(block_tables, jnp.int32), qg, k, v)
    return out.reshape(b, h, hd)


def flash_decode_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                       block_tables: jax.Array, index: jax.Array, *,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Paged flash decode: q (B, H, hd); k, v are the PHYSICAL PAGE ARENA
    (n_pages + 1, page_size, KV, hd); ``block_tables`` (B, n_blocks) int32
    maps each row's logical block j to its arena page; ``index`` (B,) is
    each row's absolute position. The table rides the scalar-prefetch
    channel, so the gather happens in the DMA prologue: grid step
    (b, g, j) fetches page ``block_tables[b, j]`` — no materialized
    per-row contiguous copy. Masking is the same ``pos <= index``
    predicate as the slot-row kernel with logical ``pos = j * page_size +
    offset``, so pages past a row's depth (scratch page, shared-tail
    bytes) contribute exact-zero probability. The KV block equals one
    page: keep ``page_size`` a multiple of 8 (ideally 128+ on the minor-2
    dim) for TPU tiling. Returns (B, H, hd)."""
    return _flash_decode_paged_jit(q, k, v, block_tables, index,
                                   resolve_interpret(interpret))
