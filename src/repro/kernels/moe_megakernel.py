"""Fused MoE megakernel: dispatch + two-layer expert FFN + combine in ONE
Pallas launch (DESIGN.md §11).

The three-kernel pipeline (scalar-prefetch dispatch gather ->
grouped_matmul x2/x3 -> weighted combine gather) pays five kernel
launches per MoE layer and materializes the (E, C, d) expert buffer twice
in HBM. This kernel is the EXPERT-MAJOR fusion of that pipeline:

  grid (E, F/bf); for expert e and f-block j, the prologue gathers the
  expert's C token rows in-kernel through its slice of the slot-token
  table (``x`` stays resident in VMEM; the (E, C, d) buffer never exists
  in HBM), the two matmuls run back to back with the gated activation
  fused between them in f32, and the epilogue scatter-accumulates
  ``wslot[e, c] * out_c`` into each source token's output row through a
  (T, d) VMEM accumulator — the combine gather transposed into the same
  launch. The grid is O(E * F/bf) steps, NOT O(T): per-step work is
  dense matmul over the capacity block, which is what keeps the fused
  kernel ahead of the pipeline's O(slots + T) step counts in both
  interpret timing and compiled occupancy.

Index-table contract (DESIGN.md §11): capacity truncation, Gate-Drop
local validity, and serving ``token_valid`` slot masking all arrive
PRE-FOLDED into ``wcomb = topk_w * keep`` (computed inside the jit
wrapper so gradients reach the router weights, exactly like
``moe_dispatch._combine_jit``), then scattered onto slots as ``wslot``:
an unoccupied or dropped slot still runs through the expert FFN (its
gather index is clipped) but contributes with weight 0 — bit-compatible
with the buffer formulation where the row arrives zeroed.

The kernel carries a custom VJP: Pallas cannot JVP through
scalar-prefetch calls, and the backward of a fused gather-FFN-scatter is
the transpose pair ``_dispatch_bwd``/``_combine_bwd`` around the FFN
backward. Rather than hand-chaining those, the backward takes ``jax.vjp``
of the pure-jnp SLOT formulation (dispatch_ref-style gather -> einsum FFN
-> combine_ref-style weighted gather), which is algebraically that exact
chain — the slot tables ride along as integer (float0-cotangent) primals.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import fit_block, resolve_interpret


def _float0_like(a: jax.Array):
    """Zero cotangent for an integer/bool primal (custom_vjp contract)."""
    return np.zeros(np.shape(a), jax.dtypes.float0)


def _act_f32(act: str):
    return jax.nn.silu if act == "silu" else jax.nn.gelu


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------

def _make_kernel(gated: bool, act: str):
    actf = _act_f32(act)

    def kernel(x_ref, *refs):
        # refs: w_in, [w_gate], w_out, slot_token, wslot, o_ref, acc_ref
        w_in_ref = refs[0]
        w_gate_ref = refs[1] if gated else None
        w_out_ref = refs[2] if gated else refs[1]
        st_ref, ws_ref = refs[-4], refs[-3]
        o_ref, acc_ref = refs[-2], refs[-1]
        e_i = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when((e_i == 0) & (j == 0))
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        t = x_ref.shape[0]
        idx = jnp.clip(st_ref[0], 0, t - 1)                    # (C,)
        rows = jnp.take(x_ref[...], idx, axis=0)               # gather (C, d)
        rows = rows.astype(jnp.float32)
        h = jnp.dot(rows, w_in_ref[0].astype(jnp.float32),
                    preferred_element_type=jnp.float32)        # (C, bf)
        if gated:
            g = jnp.dot(rows, w_gate_ref[0].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            h = actf(g) * h
        else:
            h = actf(h)
        out = jnp.dot(h, w_out_ref[0].astype(jnp.float32),
                      preferred_element_type=jnp.float32)      # (C, d)
        contrib = ws_ref[0][:, None] * out
        acc_ref[...] = acc_ref[...].at[idx].add(contrib)       # scatter (T, d)

        @pl.when((e_i == pl.num_programs(0) - 1)
                 & (j == pl.num_programs(1) - 1))
        def _done():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return kernel


def _fused_impl(x, w_in, w_gate, w_out, wcomb, slot_token, token_slot, act,
                bf, interpret):
    t, d = x.shape
    e, _, f = w_in.shape
    s = slot_token.shape[0]
    c = s // e
    gated = w_gate is not None
    bf = fit_block(f, bf)
    grid = (e, f // bf)
    # per-slot combine weight: every kept (t, k) owns exactly one slot;
    # dropped entries scatter-add their (clipped) index with weight 0
    wslot = jnp.zeros((s,), jnp.float32).at[token_slot.reshape(-1)].add(
        wcomb.reshape(-1))

    in_specs = [pl.BlockSpec((t, d), lambda e_, j: (0, 0)),
                pl.BlockSpec((1, d, bf), lambda e_, j: (e_, 0, j))]
    operands = [x, w_in]
    if gated:
        in_specs += [pl.BlockSpec((1, d, bf), lambda e_, j: (e_, 0, j))]
        operands += [w_gate]
    in_specs += [pl.BlockSpec((1, bf, d), lambda e_, j: (e_, j, 0)),
                 pl.BlockSpec((1, c), lambda e_, j: (e_, 0)),
                 pl.BlockSpec((1, c), lambda e_, j: (e_, 0))]
    operands += [w_out, slot_token.reshape(e, c), wslot.reshape(e, c)]

    return pl.pallas_call(
        _make_kernel(gated, act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((t, d), lambda e_, j: (0, 0)),
        scratch_shapes=[pltpu.VMEM((t, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(*operands)


def _ref_forward(x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
                 token_slot, act: str, out_dtype):
    """Pure-jnp SLOT formulation of the fused kernel — the VJP oracle.

    dispatch_ref-style gather -> einsum FFN (activation in f32, matching
    ops.expert_ffn_op) -> combine_ref-style weighted gather. Algebraically
    equal to the token-major kernel: kept entries read their token's row
    from the buffer, dropped entries carry wcomb == 0.
    """
    t = x.shape[0]
    e, _, f = w_in.shape
    s = slot_token.shape[0]
    actf = _act_f32(act)
    rows = jnp.take(x, jnp.clip(slot_token, 0, t - 1), axis=0)
    buf = jnp.where(slot_valid[:, None], rows, 0)              # (S, d)
    bufe = buf.reshape(e, s // e, -1).astype(w_in.dtype)
    h = jnp.einsum("ecd,edf->ecf", bufe, w_in)
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", bufe, w_gate)
        h = actf(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = actf(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(s, -1)
    picked = jnp.take(out, jnp.clip(token_slot, 0, s - 1).reshape(-1),
                      axis=0).reshape(token_slot.shape + (out.shape[-1],))
    y = jnp.einsum("tkd,tk->td", picked.astype(jnp.float32), wcomb)
    return y.astype(out_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10))
def _fused(x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
           token_slot, act, bf, interpret):
    return _fused_impl(x, w_in, w_gate, w_out, wcomb, slot_token,
                       token_slot, act, bf, interpret)


def _fused_fwd(x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
               token_slot, act, bf, interpret):
    y = _fused(x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
               token_slot, act, bf, interpret)
    return y, (x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
               token_slot)


def _fused_bwd(act, bf, interpret, res, dy):
    (x, w_in, w_gate, w_out, wcomb, slot_token, slot_valid,
     token_slot) = res
    _, vjp = jax.vjp(
        lambda x_, wi, wg, wo, wc: _ref_forward(
            x_, wi, wg, wo, wc, slot_token, slot_valid, token_slot, act,
            dy.dtype),
        x, w_in, w_gate, w_out, wcomb)
    dx, dw_in, dw_gate, dw_out, dwcomb = vjp(dy)
    return (dx, dw_in, dw_gate, dw_out, dwcomb,
            _float0_like(slot_token), _float0_like(slot_valid),
            _float0_like(token_slot))


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.jit, static_argnames=("act", "bf", "interpret"))
def _fused_jit(x, w_in, w_gate, w_out, topk_w, keep, slot_token,
               slot_valid, token_slot, act, bf, interpret):
    s = slot_token.shape[0]
    # weights folded INSIDE the jit so gradients reach topk_w (router), and
    # capacity/validity drops (keep) zero their contribution — the fused
    # analogue of _combine_jit's `w = weights * keep`
    wcomb = (topk_w * keep).astype(jnp.float32)
    st = slot_token.astype(jnp.int32)
    sv = slot_valid
    ts = jnp.clip(token_slot, 0, s - 1).astype(jnp.int32)
    xw = x.astype(w_in.dtype)
    y = _fused(xw, w_in, w_gate, w_out, wcomb, st, sv, ts, act, bf,
               interpret)
    return y.astype(x.dtype)


def fused_moe_ffn(x: jax.Array, w_in: jax.Array, w_gate: Optional[jax.Array],
                  w_out: jax.Array, topk_w: jax.Array,
                  keep: jax.Array, slot_token: jax.Array,
                  slot_valid: jax.Array, token_slot: jax.Array, *,
                  act: str = "silu", bf: int = 512,
                  interpret: Optional[bool] = None) -> jax.Array:
    """One-launch fused MoE layer: gather + expert FFN + weighted scatter.

    x: (T, d); w_in/w_gate: (E, d, f); w_out: (E, f, d);
    topk_w/keep: (T, k) routing weights and keep mask (keep already folds
    capacity, local validity, and token_valid — see DispatchInfo);
    slot_token/slot_valid: (E*C,), token_slot: (T, k) — the RoutingTables
    gather maps that drive the in-kernel gather/scatter and the VJP's
    slot-formulation backward. Returns (T, d) in x.dtype. interpret
    resolves BEFORE the jit boundary (force_interpret stays effective,
    like every kernel in this package).
    """
    return _fused_jit(x, w_in, w_gate, w_out, topk_w, keep,
                      slot_token, slot_valid, token_slot, act, bf,
                      resolve_interpret(interpret))
