"""Pallas TPU kernels for the MoE compute hot spots + decode attention.

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jit'd
wrappers and the routing-table builders. Validated with interpret=True
on CPU; BlockSpecs are MXU-aligned for the real TPU target.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.grouped_ffn import grouped_matmul
from repro.kernels.moe_dispatch import combine, dispatch

__all__ = ["combine", "dispatch", "flash_decode", "grouped_matmul", "ops",
           "ref"]
