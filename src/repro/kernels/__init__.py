"""Pallas TPU kernels for the MoE compute hot spots + decode attention.

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jit'd
wrappers and the routing-table builders. interpret mode is auto-detected
per platform (platform.default_interpret, DESIGN.md §6): interpreter on
CPU/GPU for correctness, compiled with MXU-aligned BlockSpecs on TPU.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.grouped_ffn import grouped_matmul
from repro.kernels.moe_dispatch import combine, dispatch
from repro.kernels.moe_megakernel import fused_moe_ffn
from repro.kernels.platform import (default_interpret, force_interpret,
                                    resolve_interpret)

__all__ = ["combine", "default_interpret", "dispatch", "flash_decode",
           "flash_decode_paged", "force_interpret", "fused_moe_ffn",
           "grouped_matmul", "ops", "ref", "resolve_interpret"]
