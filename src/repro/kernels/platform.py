"""Platform detection for the Pallas kernels (DESIGN.md §6).

Every kernel wrapper takes ``interpret: bool | None``. ``None`` (the
default) means *auto*: compile for real on TPU, fall back to the Pallas
interpreter everywhere else (CPU containers, GPU hosts). This replaces the
old hard-coded ``interpret=True`` so the same call sites are
correctness-checked off-TPU and compiled on-TPU with no code change.

``force_interpret`` exists for tests and benchmarks that want to pin the
mode regardless of platform (e.g. measuring interpreter overhead).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

# None = follow the platform; True/False = forced via force_interpret().
_FORCED: Optional[bool] = None


def default_interpret() -> bool:
    """True unless running on a real TPU (Pallas TPU kernels compile only
    there; interpret mode is the portable fallback)."""
    if _FORCED is not None:
        return _FORCED
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Kernel-wrapper helper: ``None`` -> platform default."""
    return default_interpret() if interpret is None else bool(interpret)


@contextlib.contextmanager
def force_interpret(value: bool):
    """Pin interpret mode inside the context (tests/benchmarks)."""
    global _FORCED
    prev = _FORCED
    _FORCED = bool(value)
    try:
        yield
    finally:
        _FORCED = prev


def fit_block(n: int, requested: int) -> int:
    """Largest divisor of ``n`` that is <= ``requested``.

    Production shapes are multiples of 128 so the MXU-aligned request wins;
    toy/test shapes degrade to a smaller exact tile instead of asserting.
    """
    b = max(1, min(requested, n))
    while n % b:
        b -= 1
    return b
