"""Paged KV cache tests (repro.serve.paged + PagedScheduler, DESIGN.md §13).

Three regression anchors:

  * ALLOCATOR SAFETY — randomized alloc/incref/decref schedules never
    double-free, never leak (free + referenced == n_pages at every step),
    and misuse (decref of a free page, incref of a free page) raises.
  * BITWISE PARITY — the paged scheduler emits token-for-token what the
    slot-pool scheduler and a per-request one-shot ``generate`` emit,
    across cache families (GQA, MLA latent, hybrid ring+meta), with
    prefix sharing ON and OFF, and across preemption/re-admission under
    page exhaustion. MoE configs get non-binding eval capacity
    (DESIGN.md §9).
  * KERNEL EQUIVALENCE (kernels lane) — ``flash_decode_paged`` over a
    permuted page arena matches ``flash_decode`` over the contiguous
    rows the block tables address.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PagedKVConfig, get_config, reduced
from repro.models import init_model
from repro.serve import (ContinuousScheduler, GenerateConfig, PageAllocator,
                         PagedScheduler, PrefixCache, Request, generate)
from repro.serve.paged import PagedLayout, ceil_div

KEY = jax.random.PRNGKey(0)


def _cfg(arch, **over):
    kw = dict(d_model=64, n_layers=2, d_ff=128, vocab=97)
    kw.update(over)
    cfg = reduced(get_config(arch), **kw)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.n_experts)))
    return cfg


def _requests(cfg, n, *, seed=1, lens=(4, 7, 11, 14), budgets=(3, 6, 9),
              prefix=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        toks = rng.integers(3, cfg.vocab - 1,
                            size=lens[i % len(lens)]).astype(np.int32)
        if prefix is not None and i % 2 == 0:
            toks = np.concatenate([prefix, toks]).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, arrival=0.0,
                            max_new=budgets[i % len(budgets)]))
    return reqs


def _serve(cls, params, cfg, gen, reqs, **kw):
    sched = cls(params, cfg, gen, prefill_buckets=(8, 16), max_seq=40, **kw)
    out = sched.run([dataclasses.replace(r) for r in reqs])
    return {r.rid: r.tokens for r in out}, sched


def _oneshot(params, cfg, gen, req):
    g = dataclasses.replace(gen, max_new=req.max_new, max_seq=40)
    res = generate(params, {"tokens": jnp.asarray(req.tokens[None])}, cfg, g)
    n = min(int(np.asarray(res.lengths)[0]), req.max_new)
    return np.asarray(res.tokens)[0, :n]


# ---------------------------------------------------------------------------
# allocator + prefix cache (host logic, no device work)
# ---------------------------------------------------------------------------

def test_allocator_fuzz_no_leak_no_double_free():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(17)
    held = []                                 # (page, extra_refs)
    for _ in range(2000):
        op = rng.integers(0, 3)
        if op == 0:                           # alloc
            p = alloc.try_alloc()
            if p is None:
                assert alloc.n_free == 0
            else:
                held.append([p, 0])
        elif op == 1 and held:                # incref a held page
            ent = held[rng.integers(len(held))]
            alloc.incref(ent[0])
            ent[1] += 1
        elif op == 2 and held:                # decref a held page
            i = rng.integers(len(held))
            p, extra = held[i]
            alloc.decref(p)
            if extra:
                held[i][1] -= 1
            else:
                held.pop(i)
        alloc.check()                         # free xor referenced, always
    for p, extra in held:
        for _ in range(extra + 1):
            alloc.decref(p)
    alloc.check()
    assert alloc.n_free == 17, "leak after randomized schedule"


def test_allocator_misuse_raises():
    alloc = PageAllocator(2)
    p = alloc.alloc()
    alloc.decref(p)
    with pytest.raises(RuntimeError):
        alloc.decref(p)                       # double free
    with pytest.raises(RuntimeError):
        alloc.incref(p)                       # incref on free page


def test_prefix_cache_refcounts_and_eviction():
    alloc = PageAllocator(4)
    cache = PrefixCache(alloc)
    a, b = alloc.alloc(), alloc.alloc()
    cache.put(("PG", 1, b"x"), [a])
    cache.put(("PG", 2, b"xy"), [a, b])
    assert alloc.ref(a) == 3 and alloc.ref(b) == 2
    cache.put(("PG", 1, b"x"), [a])           # duplicate put: no-op
    assert alloc.ref(a) == 3
    assert cache.get(("PG", 1, b"x")) == [a]
    alloc.decref(a)
    alloc.decref(b)                           # slots release their refs
    assert cache.evictable_pages() == 2
    assert cache.evict_one() and cache.evict_one()
    assert not cache.evict_one()
    alloc.check()
    assert alloc.n_free == 4


def test_layout_geometry():
    lay = PagedLayout(page_size=8, n_pages=20, seq_len=44)
    assert lay.n_blocks == ceil_div(44, 8) == 6
    assert lay.scratch == 20
    assert lay.pages_for(0) == 0
    assert lay.pages_for(8) == 1
    assert lay.pages_for(9) == 2


# ---------------------------------------------------------------------------
# validation errors
# ---------------------------------------------------------------------------

def test_engine_rejects_overflowing_budget():
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=16, max_seq=16, eos_id=-1)
    with pytest.raises(ValueError, match="pinned cache length"):
        generate(params, {"tokens": jnp.zeros((1, 8), jnp.int32)}, cfg, gen)


def test_paged_scheduler_rejects_undersized_arena():
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=8, eos_id=-1)
    with pytest.raises(ValueError, match="deadlock"):
        PagedScheduler(params, cfg, gen, max_seq=40,
                       paged=PagedKVConfig(page_size=8, n_pages=4))


def test_paged_scheduler_rejects_unpageable_arch():
    cfg = _cfg("mamba2-1.3b")                 # pure-SSM cache: no KV leaf
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=8, eos_id=-1)
    with pytest.raises(ValueError, match="nothing to page"):
        PagedScheduler(params, cfg, gen, max_seq=40)


# ---------------------------------------------------------------------------
# bitwise serving parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v3-671b", "hymba-1.5b"])
def test_paged_parity_vs_slot_and_oneshot(arch):
    over = ({"n_heads": 4, "n_kv_heads": 2, "head_dim": 16}
            if arch == "yi-6b" else {})
    cfg = _cfg(arch, **over)
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=10, eos_id=-1)
    reqs = _requests(cfg, 6)
    slot, _ = _serve(ContinuousScheduler, params, cfg, gen, reqs, n_slots=3)
    paged, ps = _serve(PagedScheduler, params, cfg, gen, reqs, n_slots=3,
                       paged=PagedKVConfig(page_size=8, n_slots_equiv=4))
    for r in reqs:
        ref = _oneshot(params, cfg, gen, r)
        assert np.array_equal(slot[r.rid], ref), (arch, "slot", r.rid)
        assert np.array_equal(paged[r.rid], ref), (arch, "paged", r.rid)
    ps._pages.check()


def test_prefix_sharing_is_bitwise_invisible():
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=8, eos_id=-1)
    prefix = np.arange(8, dtype=np.int32) + 3  # exactly one full page
    reqs = _requests(cfg, 8, prefix=prefix, lens=(4, 7, 8, 5),
                     budgets=(3, 6, 8))
    kw = dict(n_slots=3)
    shared, ss = _serve(PagedScheduler, params, cfg, gen, reqs,
                        paged=PagedKVConfig(page_size=8, n_slots_equiv=4),
                        **kw)
    unshared, _ = _serve(PagedScheduler, params, cfg, gen, reqs,
                         paged=PagedKVConfig(page_size=8, n_slots_equiv=4,
                                             prefix_caching=False), **kw)
    assert ss.stats["prefix_hits"] > 0, "trace must exercise sharing"
    for r in reqs:
        assert np.array_equal(shared[r.rid], unshared[r.rid]), r.rid
        assert np.array_equal(shared[r.rid], _oneshot(params, cfg, gen, r))
    # releasing the cache's own refs must drain the arena completely
    ss._pages.check()
    while ss._prefix.evict_one():
        pass
    assert ss._pages.n_free == ss.layout.n_pages


def test_preemption_readmission_parity_under_exhaustion():
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=20, eos_id=-1)
    reqs = _requests(cfg, 6, budgets=(20,), lens=(4, 9, 13))
    slot, _ = _serve(ContinuousScheduler, params, cfg, gen, reqs, n_slots=3)
    # n_blocks = ceil(40/4) = 10; 13 pages cannot hold 3 slots x 20 new
    # tokens -> exhaustion mid-decode forces preempt + swap-in
    paged, ps = _serve(PagedScheduler, params, cfg, gen, reqs, n_slots=3,
                       paged=PagedKVConfig(page_size=4, n_pages=13))
    assert ps.stats["preemptions"] > 0, "arena must actually exhaust"
    assert ps.stats["swap_ins"] == ps.stats["preemptions"]
    for r in reqs:
        assert np.array_equal(slot[r.rid], paged[r.rid]), r.rid
    ps._pages.check()
    while ps._prefix.evict_one():
        pass
    assert ps._pages.n_free == ps.layout.n_pages, "leak after preemptions"


def test_paged_submit_rejects_cache_overflow():
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=32, eos_id=-1)
    sched = PagedScheduler(params, cfg, gen, max_seq=40,
                           paged=PagedKVConfig(page_size=8))
    # 16 + 32 > max_seq=40: rejected up front, never silently wrapped
    with pytest.raises(ValueError, match="pinned pool cache length"):
        sched.submit(Request(rid=0, tokens=np.arange(16, dtype=np.int32),
                             arrival=0.0))


# ---------------------------------------------------------------------------
# paged flash kernel (kernels lane)
# ---------------------------------------------------------------------------

@pytest.mark.kernels
def test_flash_decode_paged_matches_contiguous():
    from repro.kernels import flash_decode, flash_decode_paged
    B, H, KV, hd, ps, nb = 4, 4, 2, 16, 8, 5
    n_pages = B * nb + 3
    k1, k2, k3 = jax.random.split(KEY, 3)
    kc = jax.random.normal(k1, (B, nb * ps, KV, hd))
    vc = jax.random.normal(k2, (B, nb * ps, KV, hd))
    q = jax.random.normal(k3, (B, H, hd))
    # scatter the contiguous rows into a permuted page arena
    perm = np.random.default_rng(0).permutation(n_pages)[:B * nb]
    tables = perm.reshape(B, nb).astype(np.int32)
    ka = jnp.zeros((n_pages + 1, ps, KV, hd))
    va = jnp.zeros((n_pages + 1, ps, KV, hd))
    ka = ka.at[tables.reshape(-1)].set(
        kc.reshape(B * nb, ps, KV, hd))
    va = va.at[tables.reshape(-1)].set(
        vc.reshape(B * nb, ps, KV, hd))
    index = jnp.asarray([3, 17, 26, nb * ps - 1], jnp.int32)
    # bs=ps: identical block partition -> identical online-softmax
    # accumulation order -> the comparison is BITWISE, not approximate
    ref = flash_decode(q, kc, vc, index, bs=ps, interpret=True)
    out = flash_decode_paged(q, ka, va, jnp.asarray(tables), index,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.kernels
def test_paged_scheduler_flash_decode_parity():
    cfg = _cfg("yi-6b", n_heads=4, n_kv_heads=2, head_dim=16)
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=8, eos_id=-1, flash_decode=True)
    reqs = _requests(cfg, 5, budgets=(3, 6, 8))
    paged, _ = _serve(PagedScheduler, params, cfg, gen, reqs, n_slots=2,
                      paged=PagedKVConfig(page_size=8, n_slots_equiv=3))
    gref = dataclasses.replace(gen, flash_decode=False)
    for r in reqs:
        assert np.array_equal(paged[r.rid],
                              _oneshot(params, cfg, gref, r)), r.rid
