"""Continuous-batching scheduler tests (repro.serve.scheduler, DESIGN.md §9).

The regression anchor is BITWISE per-request parity: a staggered
mixed-length trace served through the slot pool — bucketed padded
prefill, mid-flight admission into freed slots, per-slot positions, EOS
early exits — must emit token-for-token what a per-request one-shot
``generate`` (B=1, pool cache length) emits, across cache families and
MoE backends. MoE configs get non-binding eval capacity: expert-capacity
truncation is the one cross-request coupling of the batched decode, so
serving parity requires it off (DESIGN.md §9).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.configs import get_config, reduced
from repro.models import decode_step, init_model, prefill
from repro.serve import (ContinuousScheduler, GenerateConfig, Request,
                         generate)
from repro.serve.engine import _cache_batch_axes

KEY = jax.random.PRNGKey(0)


def _cfg(arch, backend=None):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.n_experts),
            **({"backend": backend} if backend else {}))
        cfg = dataclasses.replace(cfg, moe=moe)
    return cfg


def _requests(cfg, n, lens, budgets, stagger=0.0):
    rng = jax.random.fold_in(KEY, 1)
    reqs = []
    for i in range(n):
        L = lens[i % len(lens)]
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(rng, i), (L,), 3, cfg.vocab), np.int32)
        extras = {}
        if cfg.encdec is not None:
            if cfg.encdec.frontend == "stub":
                extras["frames"] = np.asarray(jax.random.normal(
                    jax.random.fold_in(rng, 100 + i),
                    (cfg.encdec.encoder_seq, cfg.d_model)), np.float32)
            else:
                extras["enc_tokens"] = np.asarray(jax.random.randint(
                    jax.random.fold_in(rng, 100 + i), (32,), 3, cfg.vocab),
                    np.int32)
        reqs.append(Request(rid=i, tokens=toks, extras=extras,
                            max_new=budgets[i % len(budgets)],
                            arrival=i * stagger))
    return reqs


def _assert_parity(params, cfg, gen, sched, results, reqs):
    """Every request's scheduler tokens == one-shot generate (B=1) at the
    pool's cache length, truncated to the request budget (greedy decoding
    is prefix-stable)."""
    gref = dataclasses.replace(gen, max_seq=sched.max_seq)
    assert len(results) == len(reqs)
    for res, req in zip(results, reqs):
        assert res.rid == req.rid
        batch = {"tokens": req.tokens[None]}
        for k, v in req.extras.items():
            batch[k] = v[None]
        one = generate(params, batch, cfg, gref)
        n = min(int(one.lengths[0]), req.max_new)
        ref = np.asarray(one.tokens)[0, :n]
        np.testing.assert_array_equal(res.tokens, ref,
                                      err_msg=f"request {req.rid}")


# ---------------------------------------------------------------------------
# staggered mixed-length parity across cache families / backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,backend", [
    ("yi-6b", None),               # dense dec-only, full KV cache
    ("zcode-m3-base", None),       # enc-dec MoE, oracle backend
    ("zcode-m3-base", "pallas"),   # enc-dec MoE, kernel pipeline
])
def test_continuous_matches_oneshot(arch, backend):
    cfg = _cfg(arch, backend)
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=10, eos_id=-1)
    reqs = _requests(cfg, 6, lens=[5, 8, 3, 7], budgets=[6, 10, 4],
                     stagger=1e-3)
    sched = ContinuousScheduler(params, cfg, gen, n_slots=2,
                                prefill_buckets=(8,), admit_width=2)
    results = sched.run(reqs)
    # mid-flight admission actually happened: more requests than slots,
    # so freed slots were reused while others kept decoding
    assert sched.stats["slot_reuse"] >= len(reqs) - 2
    assert sched.stats["max_concurrent"] == 2
    _assert_parity(params, cfg, gen, sched, results, reqs)


def test_continuous_exact_prefill_ssm():
    """SSM state integrates right-padding, so mamba routes through the
    exact-length prefill policy — and still matches one-shot bitwise."""
    cfg = _cfg("mamba2-1.3b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=8, eos_id=-1)
    reqs = _requests(cfg, 4, lens=[4, 7, 6], budgets=[5, 8, 3])
    sched = ContinuousScheduler(params, cfg, gen, n_slots=2,
                                prefill_buckets=(8,), admit_width=2)
    assert sched.exact_prefill
    results = sched.run(reqs)
    _assert_parity(params, cfg, gen, sched, results, reqs)


def test_continuous_eos_early_exit():
    """Declare a token the model actually emits to be EOS: the request
    that hits it retires early (freeing its slot) and both paths agree."""
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    free = GenerateConfig(max_new=10, eos_id=-1)
    reqs = _requests(cfg, 4, lens=[5, 8], budgets=[10])
    sched = ContinuousScheduler(params, cfg, free, n_slots=2,
                                prefill_buckets=(8,), admit_width=2)
    results = sched.run(reqs)
    eos = int(results[0].tokens[3])        # 4th token of request 0
    gen = dataclasses.replace(free, eos_id=eos)
    sched2 = ContinuousScheduler(params, cfg, gen, n_slots=2,
                                 prefill_buckets=(8,), admit_width=2)
    results2 = sched2.run(reqs)
    by_rid = {r.rid: r for r in results2}
    first = int(np.asarray(results[0].tokens == eos).argmax())
    assert by_rid[0].length == first + 1 < 10      # stopped at its EOS
    assert by_rid[0].tokens[-1] == eos
    _assert_parity(params, cfg, gen, sched2, results2, reqs)


def test_continuous_sampling_placement_invariant():
    """temperature>0: requests submitted with explicit seed draw from
    per-request key streams, so the pooled samples equal one-shot B=1
    samples run with the same rng/seed."""
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=6, eos_id=-1, temperature=0.8, top_k=8)
    reqs = [dataclasses.replace(r, seed=0)
            for r in _requests(cfg, 4, lens=[5, 8], budgets=[6])]
    rng = jax.random.PRNGKey(3)
    sched = ContinuousScheduler(params, cfg, gen, n_slots=2,
                                prefill_buckets=(8,), admit_width=2,
                                rng=rng)
    results = sched.run(reqs)
    gref = dataclasses.replace(gen, max_seq=sched.max_seq)
    for res, req in zip(results, reqs):
        one = generate(params, {"tokens": req.tokens[None]}, cfg, gref,
                       rng=rng)
        n = min(int(one.lengths[0]), req.max_new)
        np.testing.assert_array_equal(res.tokens,
                                      np.asarray(one.tokens)[0, :n])


# ---------------------------------------------------------------------------
# slot-pool decode primitives
# ---------------------------------------------------------------------------

def test_vector_index_decode_equals_scalar():
    """decode_step with a constant (B,) index vector is bitwise-equal to
    the scalar-index path — the invariant that makes the one-shot driver
    a thin wrapper over the pool core."""
    cfg = _cfg("yi-6b")
    params = init_model(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 6), 3, cfg.vocab)}
    lg, caches = prefill(params, batch, cfg, max_seq=12)
    tok = lg.argmax(-1).astype(jnp.int32)
    s_lg, _ = decode_step(params, caches, tok, 6, cfg)
    v_lg, _ = decode_step(params, caches, tok, jnp.array([6, 6]), cfg)
    np.testing.assert_array_equal(np.asarray(s_lg), np.asarray(v_lg))


def test_cache_batch_axes_memoized():
    """The structural cache discovery runs its eval_shape builds once per
    ModelConfig (it used to re-run on every beam-engine trace)."""
    cfg = _cfg("yi-6b")
    _cache_batch_axes(cfg)
    before = _cache_batch_axes.cache_info().hits
    _cache_batch_axes(cfg)
    assert _cache_batch_axes.cache_info().hits == before + 1


# ---------------------------------------------------------------------------
# local routing: decode executable has NO all-to-all (sharded backend)
# ---------------------------------------------------------------------------

def test_local_routing_decode_has_no_alltoall():
    """GenerateConfig.local_routing reuses the Gate-Drop local path as a
    STATIC decision: the sharded backend's pool-decode executable must
    contain zero all-to-all ops, while routed decode contains them — the
    serving twin of the trainer's dropped-chunk test."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import (GatingDropoutConfig, ModelConfig, MoEConfig)
from repro.core.moe import ParallelContext
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.serve import GenerateConfig, decode_pool_step, init_slot_pool
mesh = make_mesh((8,), ('data',))
ctx = ParallelContext(mesh=mesh)
cfg = ModelConfig(d_model=64, d_ff=128, vocab=100, n_layers=1, n_heads=2,
                  n_kv_heads=2, remat=False, dtype='float32',
                  param_dtype='float32',
                  moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                                backend='sharded',
                                gating_dropout=GatingDropoutConfig(
                                    mode='gate_drop', rate=0.3)))
params = init_model(jax.random.PRNGKey(0), cfg)
S = 8
pool = init_slot_pool(cfg, S, 32)
tok = jnp.zeros((S,), jnp.int32)
pos = jnp.full((S,), 4, jnp.int32)
alive = jnp.ones((S,), bool)
for local, name in [(False, 'routed'), (True, 'local')]:
    fn = jax.jit(lambda p, c, t, i, a: decode_pool_step(
        p, c, t, i, a, cfg, ctx, local_routing=local))
    txt = fn.lower(params, pool, tok, pos, alive).compile().as_text()
    print(name, txt.count('all-to-all'))
""")
    lines = dict(l.split() for l in out.strip().splitlines())
    assert int(lines["routed"]) > 0
    assert int(lines["local"]) == 0
