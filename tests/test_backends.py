"""Execution-backend registry (DESIGN.md §6): oracle ≡ pallas ≡ sharded.

The acceptance bar for any new backend: same routing, same Gating Dropout
branches, same numbers (within dtype tolerance) as the pure-jnp oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.configs.base import GatingDropoutConfig, ModelConfig, MoEConfig
from repro.core import (available_backends, get_backend, init_moe_params,
                        moe_apply, resolve_backend)
from repro.core.moe import ParallelContext
from repro.kernels.platform import default_interpret

KEY = jax.random.PRNGKey(0)


def _cfg(mode="gate_drop", k=1, E=4, dtype="float32", local_combine="prob"):
    return ModelConfig(
        d_model=32, d_ff=64, vocab=64, dtype=dtype,
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=64, jitter_eps=0.0,
                      gating_dropout=GatingDropoutConfig(
                          mode=mode, rate=0.3, local_combine=local_combine)))


def _apply(backend, cfg, p, x, decision):
    y, aux = get_backend(backend)(p, x, cfg, None, rng=None,
                                  decision=decision, is_training=True,
                                  token_ids=None)
    return np.asarray(y, np.float32), aux


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("mode", ["gate_drop", "gate_expert_drop"])
@pytest.mark.parametrize("decision", [False, True])
def test_backend_parity(k, mode, decision):
    """oracle ≡ pallas ≡ sharded on both the routed and dropped branches."""
    cfg = _cfg(mode=mode, k=k)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_o, aux_o = _apply("oracle", cfg, p, x, decision)
    y_p, aux_p = _apply("pallas", cfg, p, x, decision)
    y_s, aux_s = _apply("sharded", cfg, p, x, decision)
    np.testing.assert_allclose(y_o, y_p, atol=2e-5)
    np.testing.assert_allclose(y_o, y_s, atol=2e-5)
    for a in (aux_p, aux_s):
        np.testing.assert_allclose(float(aux_o["dropped_frac"]),
                                   float(a["dropped_frac"]), atol=1e-6)


def test_backend_parity_bf16():
    """Same check at bf16 activations (kernel accumulates in f32)."""
    cfg = _cfg(k=2)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.bfloat16)
    y_o, _ = _apply("oracle", cfg, p, x, False)
    y_p, _ = _apply("pallas", cfg, p, x, False)
    np.testing.assert_allclose(y_o, y_p, atol=3e-2)


def test_backend_parity_local_combine_one():
    """Gate-Drop 'one' local combine weight matches across backends."""
    cfg = _cfg(k=2, local_combine="one")
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_o, _ = _apply("oracle", cfg, p, x, True)
    y_p, _ = _apply("pallas", cfg, p, x, True)
    np.testing.assert_allclose(y_o, y_p, atol=2e-5)


def test_registry_contents_and_errors():
    assert {"oracle", "sharded", "pallas"} <= set(available_backends())
    with pytest.raises(KeyError, match="unknown MoE backend"):
        get_backend("nope")
    with pytest.raises(AssertionError):
        MoEConfig(backend="nope")


def test_resolve_auto():
    moe = MoEConfig()            # backend="auto"
    assert resolve_backend(moe, None) == "oracle"
    assert resolve_backend(moe, ParallelContext(mesh=None)) == "oracle"
    assert resolve_backend(dataclasses.replace(moe, backend="pallas"),
                           None) == "pallas"


def test_moe_apply_honours_config_backend():
    """MoEConfig.backend is the single switch: moe_apply(pallas) == direct
    pallas call, and != disabling would be caught by parity anyway."""
    cfg = _cfg(k=2)
    cfg_p = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, backend="pallas"))
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_cfg, _ = moe_apply(p, x, cfg_p, decision=False)
    y_direct, _ = _apply("pallas", cfg, p, x, False)
    np.testing.assert_array_equal(np.asarray(y_cfg, np.float32), y_direct)


def test_interpret_autodetect_off_tpu():
    """The pallas backend no longer hard-codes interpret=True: the mode is
    derived from the platform (interpreter everywhere but TPU)."""
    assert default_interpret() == (jax.default_backend() != "tpu")


@pytest.mark.parametrize("decision", [False, True])
def test_backend_under_jit_and_grad(decision):
    """The pallas pipeline must be differentiable and jittable (it runs
    inside the train step) — on the routed AND the Gate-Drop local branch
    (the latter is the only path through the valid-masked dispatch VJP)."""
    cfg = _cfg(k=2)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))

    def loss(params, backend):
        y, _ = get_backend(backend)(params, x, cfg, None, rng=None,
                                    decision=decision, is_training=True,
                                    token_ids=None)
        return (y ** 2).sum()

    g_o = jax.jit(jax.grad(lambda p_: loss(p_, "oracle")))(p)
    g_p = jax.jit(jax.grad(lambda p_: loss(p_, "pallas")))(p)
    for a, b in zip(jax.tree.leaves(g_o), jax.tree.leaves(g_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_force_interpret_not_stale_in_jit_cache():
    """interpret resolves BEFORE the jit boundary: a kernel first traced
    under the platform default must re-trace (not reuse the cached
    executable) when force_interpret changes the resolved mode."""
    from repro.kernels import force_interpret
    from repro.kernels.grouped_ffn import _gmm_jit, grouped_matmul
    x = jax.random.normal(KEY, (1, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))
    grouped_matmul(x, w)                      # traces platform default
    n0 = _gmm_jit._cache_size()
    with force_interpret(jax.default_backend() == "tpu"):
        try:
            grouped_matmul(x, w)              # opposite mode -> new trace
        except Exception:
            pass   # compiling off-TPU fails; reaching the compiler is enough
    assert _gmm_jit._cache_size() != n0


def test_pallas_backend_composes_with_mesh():
    """pallas + active mesh = sharded execution with the kernel pipeline:
    same all-to-alls and per-shard routing as `sharded`, oracle-equal."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, GatingDropoutConfig
from repro.core import get_backend, init_moe_params, moe_oracle, ParallelContext
from repro.launch.mesh import make_mesh
cfg = ModelConfig(d_model=32, d_ff=64, vocab=64, moe=MoEConfig(
    n_experts=8, top_k=2, d_ff_expert=64, jitter_eps=0.0,
    gating_dropout=GatingDropoutConfig(mode='gate_drop', rate=0.3)))
p = init_moe_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
ctx = ParallelContext(mesh=make_mesh((8,), ('data',)))
for dec in (False, True):
    y_ref, _ = moe_oracle(p, x, cfg, ep=8, decision=dec)
    y_pl, _ = jax.jit(lambda p_, x_: get_backend('pallas')(
        p_, x_, cfg, ctx, rng=None, decision=dec, is_training=True,
        token_ids=None))(p, x)
    d = float(jnp.abs(y_ref - y_pl).max())
    assert d < 2e-5, (dec, d)
print('OK')
""")
    assert "OK" in out


def test_sharded_backend_multidevice_matches_oracle():
    """Registry-selected sharded backend on a real 8-device mesh equals the
    oracle with the matching virtual shard count."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, GatingDropoutConfig
from repro.core import get_backend, init_moe_params, moe_oracle, ParallelContext
from repro.launch.mesh import make_mesh
cfg = ModelConfig(d_model=32, d_ff=64, vocab=64, moe=MoEConfig(
    n_experts=8, top_k=2, d_ff_expert=64, jitter_eps=0.0,
    gating_dropout=GatingDropoutConfig(mode='gate_drop', rate=0.3)))
p = init_moe_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
ctx = ParallelContext(mesh=make_mesh((8,), ('data',)))
for dec in (False, True):
    y_ref, _ = moe_oracle(p, x, cfg, ep=8, decision=dec)
    y_sh, _ = get_backend('sharded')(p, x, cfg, ctx, rng=None, decision=dec,
                                     is_training=True, token_ids=None)
    d = float(jnp.abs(y_ref - y_sh).max())
    assert d < 2e-5, (dec, d)
print('OK')
""")
    assert "OK" in out
