"""Per-architecture smoke tests (reduced configs) + decode==forward checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, train_batch
from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import TrainConfig
from repro.models import (decode_step, init_model, layer_plan, model_apply,
                          prefill)
from repro.training import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced variant (<=2 layers, d<=256, <=4 experts): one forward and one
    train step on CPU; shape + finiteness assertions."""
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    params = init_model(KEY, cfg)
    B, L = 2, 32
    batch = train_batch(cfg, KEY, B, L)
    logits, aux = model_apply(params, batch, cfg, rng=KEY, decision=None)
    assert logits.shape == (B, L, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    tc = TrainConfig(lr=1e-3, warmup_steps=10)
    state = init_train_state(params, tc)
    step = make_train_step(cfg, tc, jit=False)
    state, m = step(state, batch, None)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    assert float(m["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_model(KEY, cfg)
    B, L = 2, 17
    batch = make_batch(cfg, KEY, B, L)
    full, _ = model_apply(params, batch, cfg, decision=None,
                          is_training=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :L - 1]
    lg, caches = prefill(params, pre, cfg, max_seq=32)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, L - 2]), atol=2e-4)
    lg2, _ = decode_step(params, caches, batch["tokens"][:, L - 1:L],
                         L - 1, cfg)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, L - 1]), atol=2e-4)


def test_multi_token_decode_chain():
    """Decode 8 tokens sequentially == teacher-forced forward."""
    cfg = reduced(get_config("yi-6b"))
    params = init_model(KEY, cfg)
    B, L, n = 2, 24, 8
    batch = make_batch(cfg, KEY, B, L)
    full, _ = model_apply(params, batch, cfg, decision=None,
                          is_training=False)
    pre = {"tokens": batch["tokens"][:, :L - n]}
    _, caches = prefill(params, pre, cfg, max_seq=32)
    for i in range(n):
        pos = L - n + i
        lg, caches = decode_step(params, caches,
                                 batch["tokens"][:, pos:pos + 1], pos, cfg)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, pos]), atol=3e-4)


def test_layer_plan_compression():
    # dense: one segment
    assert len(layer_plan(reduced(get_config("yi-6b")))) == 1
    # deepseek: dense prefix + moe run
    segs = layer_plan(get_config("deepseek-v3-671b"))
    assert len(segs) == 2
    assert segs[0].repeats == 3 and not segs[0].pattern[0].moe
    assert segs[1].repeats == 58 and segs[1].pattern[0].moe
    # vlm: periodic [cross, self x4]
    segs = layer_plan(get_config("llama-3.2-vision-90b"))
    assert len(segs) == 1 and len(segs[0].pattern) == 5
    assert segs[0].pattern[0].gated_cross and segs[0].repeats == 20
    # hymba: 3 global layers split the stack into 5 segments
    segs = layer_plan(get_config("hymba-1.5b"))
    assert sum(s.repeats * len(s.pattern) for s in segs) == 32
    # total layer counts always preserved
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        segs = layer_plan(cfg)
        assert sum(s.repeats * len(s.pattern) for s in segs) == cfg.n_layers


def test_sliding_window_attention_limits_context():
    """Token far beyond the window must not influence logits."""
    cfg = reduced(get_config("h2o-danube-3-4b"), sliding_window=8)
    params = init_model(KEY, cfg)
    B, L = 1, 32
    t1 = jax.random.randint(KEY, (B, L), 3, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab)   # mutate token 0
    l1, _ = model_apply(params, {"tokens": t1}, cfg, is_training=False)
    l2, _ = model_apply(params, {"tokens": t2}, cfg, is_training=False)
    # with 2 layers x window 8, receptive field < 16: last logits equal
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)
    assert np.abs(np.asarray(l1[:, 0] - l2[:, 0])).max() > 1e-3


def test_mtp_aux_present_for_deepseek():
    cfg = reduced(get_config("deepseek-v3-671b"))
    assert cfg.mtp
    params = init_model(KEY, cfg)
    batch = train_batch(cfg, KEY)
    _, aux = model_apply(params, batch, cfg, rng=KEY, is_training=True,
                         return_hidden=True)
    assert "mtp_hidden" in aux
