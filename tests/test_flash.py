"""Flash attention (pure-JAX custom-VJP) vs full attention, fwd + bwd."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import full_attention
from repro.models.flash import flash_attention

KEY = jax.random.PRNGKey(0)


def _qkv(B, Lq, Lk, H, KV, hd, hdv=None, dtype=jnp.float32):
    hdv = hdv or hd
    q = jax.random.normal(KEY, (B, Lq, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Lk, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Lk, KV, hdv), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 13), (False, 0)])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_forward_matches_full(causal, window, kv):
    q, k, v = _qkv(2, 40, 40, 4, kv, 16)
    o1 = flash_attention(q, k, v, causal, window, 0, 0, 16, 16)
    o2 = full_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_grad_matches_full():
    q, k, v = _qkv(2, 33, 33, 4, 2, 16)
    f1 = lambda *a: (flash_attention(*a, True, 11, 0, 0, 16, 16) ** 2).sum()
    f2 = lambda *a: (full_attention(*a, causal=True, window=11) ** 2).sum()
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_mla_style_distinct_v_dim():
    q, k, v = _qkv(1, 48, 48, 4, 4, 24, hdv=16)
    o1 = flash_attention(q, k, v, True, 0, 0, 0, 16, 16)
    o2 = full_attention(q, k, v, causal=True)
    assert o1.shape[-1] == 16
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_q_offset_cross_chunk():
    """Decode-style: queries offset deep into the key sequence."""
    q, k, v = _qkv(1, 8, 64, 2, 2, 16)
    o1 = flash_attention(q, k, v, True, 0, 56, 0, 8, 16)
    o2 = full_attention(q, k, v, causal=True,
                        qpos=56 + jnp.arange(8), kpos=jnp.arange(64))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("lq,lk,qc,kc,causal", [
    # fixed sweep over ragged chunk combinations (was hypothesis-driven)
    (3, 8, 8, 8, False),
    (17, 60, 16, 32, True),
    (50, 50, 32, 8, True),
    (33, 40, 8, 16, False),
    (5, 64, 32, 32, True),
    (48, 48, 16, 16, False),
    (41, 59, 32, 16, True),
    (26, 31, 8, 32, False),
])
def test_chunking_invariance(lq, lk, qc, kc, causal):
    """Result must be independent of chunk sizes (incl. ragged pads)."""
    if causal:
        lq = min(lq, lk)
    q, k, v = _qkv(1, lq, lk, 2, 1, 8)
    off = lk - lq if causal else 0
    o1 = flash_attention(q, k, v, causal, 0, off, 0, qc, kc)
    o2 = full_attention(q, k, v, causal=causal,
                        qpos=off + jnp.arange(lq), kpos=jnp.arange(lk))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_bf16_tolerance():
    q, k, v = _qkv(1, 32, 32, 2, 2, 16, dtype=jnp.bfloat16)
    o1 = flash_attention(q, k, v, True, 0, 0, 0, 16, 16)
    o2 = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=2e-2)
