import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with N simulated CPU devices.
    Multi-device tests must run out-of-process because jax locks the device
    count at first init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


_GUARDED_MODULES = ("test_trainer", "test_serve", "test_scheduler",
                    "test_obs")


@pytest.fixture(autouse=True)
def _no_hidden_host_transfers(request):
    """Transfer guard over the trainer/serving test modules (DESIGN.md
    §12): library code under src/repro must not pull device buffers to
    host implicitly (np.asarray / float / .item on a jax Array) — the
    sanctioned sync is an explicit jax.device_get. Test-file code may
    pull freely (asserting on values is what tests do); only events
    originating inside src/repro fail."""
    mod = request.module.__name__.rsplit(".", 1)[-1]
    if mod not in _GUARDED_MODULES:
        yield
        return
    from repro.analysis.hostsync import guard_host_transfers
    with guard_host_transfers(mode="record") as events:
        yield
    bad = [ev for ev in events
           if not ev.sanctioned and not ev.internal
           and os.path.join("src", "repro") in ev.origin]
    if bad:
        lines = "\n".join(f"  {ev.method} at {ev.origin}"
                          for ev in {(e.method, e.origin): e
                                     for e in bad}.values())
        pytest.fail(
            f"implicit device->host transfer(s) in library code "
            f"(use jax.device_get):\n{lines}", pytrace=False)


def make_batch(cfg, key, B=2, L=33):
    batch = {"tokens": jax.random.randint(key, (B, L), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(key, (B, 32), 3, cfg.vocab)
    return batch


def train_batch(cfg, key, B=2, L=32):
    b = make_batch(cfg, key, B, L)
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    b["loss_mask"] = jnp.ones((B, L), jnp.float32)
    return b
