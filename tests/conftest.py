import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with N simulated CPU devices.
    Multi-device tests must run out-of-process because jax locks the device
    count at first init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def make_batch(cfg, key, B=2, L=33):
    batch = {"tokens": jax.random.randint(key, (B, L), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (B, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(key, (B, 32), 3, cfg.vocab)
    return batch


def train_batch(cfg, key, B=2, L=32):
    b = make_batch(cfg, key, B, L)
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    b["loss_mask"] = jnp.ones((B, L), jnp.float32)
    return b
