"""launch/env.py: the process-environment perf preset.

Pure host logic — no jax, no subprocess exec. The tcmalloc probe is
driven by monkeypatching ``os.path.exists`` so the tests pin BOTH
branches (present/absent) regardless of what the host has installed.
"""
import os

import pytest

from repro.launch import env as E


def _with_tcmalloc(monkeypatch, path):
    """Make exactly ``path`` (a TCMALLOC_CANDIDATES entry or None) exist."""
    monkeypatch.setattr(os.path, "exists", lambda p: p == path)


# ------------------------------------------------------------- find_tcmalloc

def test_find_tcmalloc_picks_first_existing(monkeypatch):
    want = E.TCMALLOC_CANDIDATES[1]
    _with_tcmalloc(monkeypatch, want)
    assert E.find_tcmalloc() == want


def test_find_tcmalloc_none_when_absent(monkeypatch):
    _with_tcmalloc(monkeypatch, None)
    assert E.find_tcmalloc() is None


# ----------------------------------------------------------- XLA flag merge

def test_merge_adds_perf_flags_to_empty():
    merged = E._merge_xla_flags("")
    for f in E.XLA_PERF_FLAGS:
        assert f in merged.split()


def test_merge_caller_wins_on_same_flag():
    """A caller-set value of the same flag must NOT be clobbered or
    duplicated — only flags the caller didn't set are added."""
    merged = E._merge_xla_flags("--xla_step_marker_location=0")
    flags = merged.split()
    assert flags.count("--xla_step_marker_location=0") == 1
    assert "--xla_step_marker_location=1" not in flags


def test_merge_preserves_unrelated_flags():
    merged = E._merge_xla_flags("--xla_force_host_platform_device_count=8")
    assert "--xla_force_host_platform_device_count=8" in merged.split()
    assert "--xla_step_marker_location=1" in merged.split()


# ----------------------------------------------------------------- perf_env

def test_perf_env_sets_preload_when_tcmalloc_found(monkeypatch):
    tc = E.TCMALLOC_CANDIDATES[0]
    _with_tcmalloc(monkeypatch, tc)
    delta = E.perf_env({})
    assert delta["LD_PRELOAD"] == tc
    assert delta["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" in delta


def test_perf_env_prepends_not_duplicates_preload(monkeypatch):
    tc = E.TCMALLOC_CANDIDATES[0]
    _with_tcmalloc(monkeypatch, tc)
    # existing preload of something else -> prepended
    delta = E.perf_env({"LD_PRELOAD": "/lib/other.so"})
    assert delta["LD_PRELOAD"] == tc + os.pathsep + "/lib/other.so"
    # already preloaded -> untouched
    delta = E.perf_env({"LD_PRELOAD": tc})
    assert "LD_PRELOAD" not in delta


def test_perf_env_fallback_without_tcmalloc(monkeypatch):
    """No tcmalloc on the host: the preset must still work — no
    LD_PRELOAD of a missing path (which would break every child exec)."""
    _with_tcmalloc(monkeypatch, None)
    delta = E.perf_env({})
    assert "LD_PRELOAD" not in delta
    assert "--xla_step_marker_location=1" in delta["XLA_FLAGS"]


def test_perf_env_respects_caller_values(monkeypatch):
    _with_tcmalloc(monkeypatch, None)
    base = {"TF_CPP_MIN_LOG_LEVEL": "0",
            "XLA_FLAGS": "--xla_step_marker_location=0"}
    delta = E.perf_env(base)
    assert "TF_CPP_MIN_LOG_LEVEL" not in delta
    assert "XLA_FLAGS" not in delta     # nothing to add -> no churn


def test_apply_mutates_and_returns_delta(monkeypatch):
    _with_tcmalloc(monkeypatch, None)
    environ = {}
    delta = E.apply(environ)
    assert environ == delta
    assert "--xla_step_marker_location=1" in environ["XLA_FLAGS"]


# --------------------------------------------------------------------- CLI

def test_main_sh_emits_evalable_exports(monkeypatch, capsys):
    _with_tcmalloc(monkeypatch, E.TCMALLOC_CANDIDATES[0])
    monkeypatch.setattr(os, "environ", {})
    E.main(["--sh"])
    out = capsys.readouterr().out
    lines = [l for l in out.splitlines() if l]
    assert lines == sorted(lines)
    for line in lines:
        assert line.startswith("export ")
        k, v = line[len("export "):].split("=", 1)
        assert v.startswith("'") and v.endswith("'")
    assert any(l.startswith("export LD_PRELOAD=") for l in lines)


def test_main_plain_prints_kv(monkeypatch, capsys):
    _with_tcmalloc(monkeypatch, None)
    monkeypatch.setattr(os, "environ", {})
    E.main([])
    out = capsys.readouterr().out
    assert "XLA_FLAGS=" in out
    assert "export" not in out


def test_main_exec_applies_preset(monkeypatch):
    """`-- cmd` re-execs with the preset merged into the environment."""
    _with_tcmalloc(monkeypatch, None)
    seen = {}

    def fake_exec(prog, argv, env):
        seen.update(prog=prog, argv=argv, env=env)

    monkeypatch.setattr(os, "execvpe", fake_exec)
    monkeypatch.setattr(os, "environ", {"HOME": "/root"})
    E.main(["--", "echo", "hi"])
    assert seen["prog"] == "echo" and seen["argv"] == ["echo", "hi"]
    assert seen["env"]["HOME"] == "/root"
    assert "--xla_step_marker_location=1" in seen["env"]["XLA_FLAGS"]


def test_sh_quote_single_quotes():
    assert E._sh_quote("a'b") == "'a'\\''b'"
