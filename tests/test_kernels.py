"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import router as R
from repro.kernels import flash_decode, grouped_matmul, ops, ref

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("e,c,d,f", [(2, 128, 128, 128), (4, 256, 128, 256),
                                     (1, 128, 256, 128), (8, 128, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, c, d, f, dtype):
    x = jax.random.normal(KEY, (e, c, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f), dtype)
    y = grouped_matmul(x, w, interpret=True)
    y_ref = ref.grouped_matmul_ref(x, w)
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


@pytest.mark.parametrize("bc,bf,bd", [(64, 64, 64), (128, 128, 128)])
def test_grouped_matmul_block_invariance(bc, bf, bd):
    x = jax.random.normal(KEY, (2, 128, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128))
    y = grouped_matmul(x, w, bc=bc, bf=bf, bd=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.grouped_matmul_ref(x, w)),
                               atol=1e-4)


@pytest.mark.parametrize("E,k,cap,T", [(4, 1, 16, 64), (8, 2, 8, 64),
                                       (2, 2, 64, 32)])
def test_dispatch_combine_kernels_match_router(E, k, cap, T):
    moe = MoEConfig(n_experts=E, top_k=k, jitter_eps=0.0)
    x = jax.random.normal(KEY, (T, 128))
    wr = jax.random.normal(jax.random.PRNGKey(1), (128, E))
    rr = R.route(wr, x, moe, is_training=False)
    info = R.dispatch_info(rr, E, cap)
    buf_ref = R.dispatch(x, info, E, cap)
    buf = ops.moe_dispatch_op(x, info, E, cap, interpret=True)
    np.testing.assert_allclose(np.asarray(buf), np.asarray(buf_ref),
                               atol=1e-6)
    y_ref = R.combine(buf_ref, info)
    y = ops.moe_combine_op(buf, info, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_dispatch_ref_oracle_agrees():
    """build_slot_maps + ref.dispatch_ref == router.dispatch."""
    moe = MoEConfig(n_experts=4, top_k=1, jitter_eps=0.0)
    x = jax.random.normal(KEY, (32, 16))
    wr = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    rr = R.route(wr, x, moe, is_training=False)
    info = R.dispatch_info(rr, 4, 8)
    st_, sv, _ = ops.build_slot_maps(info, 4, 8)
    buf = ref.dispatch_ref(x, st_, sv).reshape(4, 8, 16)
    np.testing.assert_allclose(np.asarray(buf),
                               np.asarray(R.dispatch(x, info, 4, 8)),
                               atol=1e-6)


@pytest.mark.parametrize("h,kv,hd,s,bs", [(8, 2, 64, 512, 128),
                                          (4, 4, 128, 256, 256),
                                          (8, 1, 64, 384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(h, kv, hd, s, bs, dtype):
    b = 2
    q = jax.random.normal(KEY, (b, h, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd), dtype)
    idx = s // 2 + 3
    o = flash_decode(q, k, v, idx, bs=bs, interpret=True)
    o_ref = ref.flash_decode_ref(q, k, v, idx)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=atol)


@pytest.mark.parametrize("idx", [0, 7, 63, 64, 128, 200, 255])
@pytest.mark.parametrize("bs", [64, 128])
def test_flash_decode_index_property(idx, bs):
    """Changing keys BEYOND idx never changes the output."""
    b, h, kv, hd, s = 1, 2, 1, 32, 256
    q = jax.random.normal(KEY, (b, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    o1 = flash_decode(q, k, v, idx, bs=bs, interpret=True)
    k2 = k.at[:, idx + 1:].set(99.0)
    v2 = v.at[:, idx + 1:].set(-99.0)
    o2 = flash_decode(q, k2, v2, idx, bs=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_expert_ffn_op_matches_moe_ffn():
    """Full kernel-backed gated expert FFN vs jnp einsum path."""
    e, c, d, f = 2, 128, 128, 256
    buf = jax.random.normal(KEY, (e, c, d))
    w_in = jax.random.normal(jax.random.PRNGKey(1), (e, d, f)) * 0.1
    w_g = jax.random.normal(jax.random.PRNGKey(2), (e, d, f)) * 0.1
    w_out = jax.random.normal(jax.random.PRNGKey(3), (e, f, d)) * 0.1
    y = ops.expert_ffn_op(buf, w_in, w_g, w_out, "silu", interpret=True)
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_g)
    y_ref = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)
