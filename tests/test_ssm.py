"""Mamba-2 SSD: chunked == naive recurrence; decode == prefill state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_model, model_apply
from repro.models.ssm import (init_ssm, init_ssm_cache, ssd_chunked,
                              ssm_apply, ssm_decode)

KEY = jax.random.PRNGKey(0)


def naive_ssd(xh, dt, a, bs, cs):
    b, l, h, p = xh.shape
    g, n = bs.shape[2], bs.shape[3]
    rep = h // g
    be = jnp.repeat(bs, rep, 2)
    ce = jnp.repeat(cs, rep, 2)
    hst = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        dec = jnp.exp(dt[:, t] * a)
        hst = hst * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], xh[:, t], be[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", ce[:, t], hst))
    return jnp.stack(ys, 1), hst


@pytest.mark.parametrize("l,chunk,h,g,seed", [
    # fixed sweep (was hypothesis-driven)
    (16, 8, 2, 1, 0), (32, 16, 4, 2, 1), (48, 8, 4, 1, 2),
    (32, 8, 2, 2, 3), (48, 16, 2, 1, 4), (16, 16, 4, 2, 5),
])
def test_ssd_chunked_equals_recurrence(l, chunk, h, g, seed):
    if h % g:
        g = 1
    b, p, n = 2, 8, 8
    k = jax.random.PRNGKey(seed)
    xh = jax.random.normal(k, (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                           (b, l, h))) * 0.5
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(seed + 2), (h,)) * 0.3)
    bs = jax.random.normal(jax.random.PRNGKey(seed + 3), (b, l, g, n))
    cs = jax.random.normal(jax.random.PRNGKey(seed + 4), (b, l, g, n))
    y, hf = ssd_chunked(xh, dt, a, bs, cs, chunk)
    y_ref, h_ref = naive_ssd(xh, dt, a, bs, cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), atol=2e-4)


def test_ssm_decode_continues_prefix():
    """ssm_apply(x[:, :t+1])[-1] == decode step after prefix state."""
    cfg = reduced(get_config("mamba2-1.3b"))
    prm = init_ssm(KEY, cfg, jnp.float32)
    b, l = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, l, cfg.d_model))
    y_full = ssm_apply(prm, x, cfg)
    # build cache from prefix then decode last token
    from repro.models.transformer import _fill_ssm_cache
    cache = _fill_ssm_cache(prm, x[:, :l - 1], cfg)
    y_dec, _ = ssm_decode(prm, x[:, l - 1:], cache, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, -1]), atol=1e-4)


def test_mamba_lm_long_context_state_is_constant_size():
    cfg = reduced(get_config("mamba2-1.3b"))
    cache = init_ssm_cache(cfg, batch=1, dtype=jnp.float32)
    assert cache["h"].shape[0] == 1
    # O(1) in sequence length by construction (no seq dim in the cache)
    assert all("seq" not in str(k) for k in cache)
    assert cache["conv"].shape[1] == cfg.ssm.conv_kernel - 1
