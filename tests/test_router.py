"""Router unit + property tests (dispatch/combine round-trip, balance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import router as R


def _route(T=64, E=8, k=2, d=16, router="softmax", seed=0, jitter=0.0):
    moe = MoEConfig(n_experts=E, top_k=k, router_type=router,
                    jitter_eps=jitter)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (T, d))
    wr = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, E))
    tok = jax.random.randint(key, (T,), 0, 1000)
    rr = R.route(wr, x, moe, is_training=False, token_ids=tok)
    return moe, x, rr


def test_topk_weights_normalized():
    _, _, rr = _route(k=4)
    np.testing.assert_allclose(np.asarray(rr.topk_w.sum(-1)), 1.0, rtol=1e-5)


def test_top1_weight_is_prob():
    moe, _, rr = _route(k=1)
    # paper eq (2): combine weight for k=1 is the raw softmax prob
    assert float(rr.topk_w.max()) < 1.0
    np.testing.assert_allclose(
        np.asarray(rr.topk_w[:, 0]),
        np.asarray(jnp.take_along_axis(rr.probs, rr.topk_idx, 1)[:, 0]),
        rtol=1e-5)


def test_topk_indices_distinct():
    _, _, rr = _route(k=4)
    idx = np.asarray(rr.topk_idx)
    for row in idx:
        assert len(set(row.tolist())) == len(row)


@pytest.mark.parametrize("router", ["softmax", "sigmoid", "hash"])
def test_roundtrip_exact_when_capacity_ample(router):
    """capacity >= T => dispatch->combine with weight 1 reconstructs tokens."""
    moe, x, rr = _route(T=32, E=4, k=1, router=router)
    rr = rr._replace(topk_w=jnp.ones_like(rr.topk_w))
    info = R.dispatch_info(rr, 4, cap=32)
    assert bool(info.keep.all())
    buf = R.dispatch(x, info, 4, 32)
    y = R.combine(buf, info)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5)


def test_capacity_drops_lowest_priority():
    moe, x, rr = _route(T=64, E=2, k=1)
    info = R.dispatch_info(rr, 2, cap=4)
    # each expert keeps at most 4
    kept = np.asarray(info.keep[:, 0])
    idx = np.asarray(rr.topk_idx[:, 0])
    for e in range(2):
        assert kept[idx == e].sum() <= 4
        # priority is token order: kept ones are the first assigned
        rows = np.where(idx == e)[0]
        assert kept[rows[:kept[idx == e].sum()]].all()


def test_balance_loss_uniform_is_one():
    E, T = 8, 800
    moe = MoEConfig(n_experts=E, top_k=1)
    probs = jnp.full((T, E), 1.0 / E)
    idx = (jnp.arange(T) % E)[:, None].astype(jnp.int32)
    rr = R.RouteResult(idx, jnp.ones((T, 1)), probs, jnp.zeros((T, E)))
    assert abs(float(R.balance_loss(rr, moe)) - 1.0) < 1e-5


def test_balance_loss_collapse_is_E():
    E, T = 8, 128
    moe = MoEConfig(n_experts=E, top_k=1)
    probs = jnp.zeros((T, E)).at[:, 0].set(1.0)
    idx = jnp.zeros((T, 1), jnp.int32)
    rr = R.RouteResult(idx, jnp.ones((T, 1)), probs, jnp.zeros((T, E)))
    assert abs(float(R.balance_loss(rr, moe)) - E) < 1e-4


def test_hash_router_deterministic_and_gateless():
    moe, x, rr = _route(router="hash")
    _, _, rr2 = _route(router="hash")
    np.testing.assert_array_equal(np.asarray(rr.topk_idx),
                                  np.asarray(rr2.topk_idx))


def test_local_routing_restricted():
    moe = MoEConfig(n_experts=8, top_k=2, jitter_eps=0.0)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 16))
    wr = jax.random.normal(key, (16, 8))
    rr = R.route(wr, x, moe, is_training=False, expert_lo=4, n_local=4)
    idx = np.asarray(rr.topk_idx)
    w = np.asarray(rr.topk_w)
    valid = (idx >= 4) & (idx < 8)
    assert (w[~valid] < 1e-6).all()
    assert valid[:, 0].all()      # top choice always local
    # restricted softmax renormalizes within the local group
    np.testing.assert_allclose(np.asarray(rr.probs).sum(1), 1.0, rtol=1e-5)


@pytest.mark.parametrize("t,e,k,seed", [
    # fixed sweep (was hypothesis-driven)
    (4, 2, 1, 0), (64, 16, 4, 1), (17, 3, 2, 2), (33, 8, 3, 3),
    (48, 5, 1, 4), (64, 2, 2, 5), (7, 16, 1, 6), (40, 11, 4, 7),
])
def test_positions_are_valid_ranks(t, e, k, seed):
    k = min(k, e)
    moe = MoEConfig(n_experts=e, top_k=k, jitter_eps=0.0)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, 8))
    wr = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, e))
    rr = R.route(wr, x, moe, is_training=False)
    info = R.dispatch_info(rr, e, cap=t)
    idx = np.asarray(rr.topk_idx.reshape(-1))
    pos = np.asarray(info.pos.reshape(-1))
    # within each expert, positions are 0..count-1 and unique
    for ee in range(e):
        pp = np.sort(pos[idx == ee])
        np.testing.assert_array_equal(pp, np.arange(len(pp)))


@pytest.mark.parametrize("t,e,cap,seed", [
    # fixed sweep (was hypothesis-driven)
    (8, 2, 1, 0), (48, 8, 16, 1), (23, 4, 3, 2), (32, 2, 16, 3),
    (41, 8, 7, 4), (16, 4, 1, 5), (48, 2, 9, 0), (29, 8, 2, 1),
])
def test_combine_is_masked_weighted_gather(t, e, cap, seed):
    moe = MoEConfig(n_experts=e, top_k=1, jitter_eps=0.0)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, 8))
    wr = jax.random.normal(jax.random.PRNGKey(seed + 7), (8, e))
    rr = R.route(wr, x, moe, is_training=False)
    info = R.dispatch_info(rr, e, cap)
    buf = R.dispatch(x, info, e, cap)
    y = R.combine(buf, info)
    # dropped tokens must produce exactly zero
    dropped = ~np.asarray(info.keep[:, 0])
    assert np.abs(np.asarray(y)[dropped]).max(initial=0.0) == 0.0
    # kept tokens: y = w * x
    keptv = np.asarray(info.keep[:, 0])
    w = np.asarray(rr.topk_w[:, 0])
    np.testing.assert_allclose(np.asarray(y)[keptv],
                               (w[:, None] * np.asarray(x))[keptv], rtol=1e-4)
