"""Decoding-engine tests (repro.serve, DESIGN.md §7).

The regression anchor is ``test_prefill_decode_equals_forward_everywhere``:
prefill + teacher-forced decode must reproduce the full-sequence forward
logits at EVERY position. The pre-engine ``greedy_bleu`` fed decode index
0 after a 1-token prefill (overwriting the BOS cache slot); this test
fails under that off-by-one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_config, reduced
from repro.models import decode_step, init_model, model_apply, prefill
from repro.serve import GenerateConfig, GenerateResult, generate

KEY = jax.random.PRNGKey(0)


def _setup(arch, B=2, L=16, ample_capacity=False):
    cfg = reduced(get_config(arch))
    if ample_capacity and cfg.moe is not None:
        # capacity >= T in BOTH the full forward (T = B*L) and the decode
        # step (T = B): expert-capacity truncation is an orthogonal,
        # token-count-dependent semantic (a 2-token decode step drops
        # tokens a 32-token forward keeps), and would mask the indexing
        # contract this file pins
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.n_experts)))
    params = init_model(KEY, cfg)
    batch = make_batch(cfg, KEY, B, L)
    return cfg, params, batch


# ---------------------------------------------------------------------------
# cache-indexing contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-6b", "zcode-m3-base"])
@pytest.mark.parametrize("prompt_len", [1, 7])
def test_prefill_decode_equals_forward_everywhere(arch, prompt_len):
    """Prefill P tokens, then teacher-force decode positions P..L-1: logits
    must match the full forward at every single position (decoder-only AND
    enc-dec). First post-prefill decode index is P — never 0."""
    cfg, params, batch = _setup(arch, ample_capacity=True)
    L = batch["tokens"].shape[1]
    full, _ = model_apply(params, batch, cfg, decision=None,
                          is_training=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :prompt_len]
    lg, caches = prefill(params, pre, cfg, max_seq=L + 1)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, prompt_len - 1]),
                               atol=2e-4)
    for pos in range(prompt_len, L):
        lg, caches = decode_step(params, caches,
                                 batch["tokens"][:, pos:pos + 1], pos, cfg)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, pos]), atol=3e-4,
                                   err_msg=f"position {pos}")


@pytest.mark.parametrize("arch", ["yi-6b", "zcode-m3-base"])
def test_engine_greedy_matches_reference_loop(arch):
    """The compiled while_loop == a hand-rolled (correctly indexed) Python
    loop over prefill/decode_step, token for token."""
    cfg, params, batch = _setup(arch, B=2, L=6)
    P, N = batch["tokens"].shape[1], 8
    lg, caches = prefill(params, batch, cfg, max_seq=P + N)
    cur = lg.argmax(-1).astype(jnp.int32)
    ref = [np.asarray(cur)[:, 0]]
    for i in range(N - 1):
        lg, caches = decode_step(params, caches, cur, P + i, cfg)
        cur = lg.argmax(-1).astype(jnp.int32)
        ref.append(np.asarray(cur)[:, 0])
    res = generate(params, batch, cfg, GenerateConfig(max_new=N, eos_id=-1))
    np.testing.assert_array_equal(np.asarray(res.tokens), np.stack(ref, 1))
    assert int(res.steps) == N - 1
    assert np.asarray(res.lengths).tolist() == [N, N]


# ---------------------------------------------------------------------------
# EOS early exit + masking
# ---------------------------------------------------------------------------

def test_engine_eos_early_exit_and_masking():
    cfg, params, batch = _setup("yi-6b", B=1, L=5)
    free = generate(params, batch, cfg, GenerateConfig(max_new=10, eos_id=-1))
    toks = np.asarray(free.tokens)[0]
    # declare the 3rd generated token to be EOS and rerun: generation is
    # deterministic, so the engine must emit the same prefix, mark done,
    # pad the rest, and exit the loop early
    eos = int(toks[2])
    gen = GenerateConfig(max_new=10, eos_id=eos, pad_id=0)
    res = generate(params, batch, cfg, gen)
    out = np.asarray(res.tokens)[0]
    first = np.asarray(toks == eos).argmax()      # earliest EOS occurrence
    np.testing.assert_array_equal(out[:first + 1], toks[:first + 1])
    assert (out[first + 1:] == 0).all()
    assert int(res.lengths[0]) == first + 1
    assert int(res.steps) <= first + 1 < 9        # exited before max_new


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_engine_topk1_sampling_equals_greedy():
    cfg, params, batch = _setup("yi-6b", B=2, L=5)
    g = generate(params, batch, cfg, GenerateConfig(max_new=6, eos_id=-1))
    s = generate(params, batch, cfg,
                 GenerateConfig(max_new=6, eos_id=-1, temperature=1.0,
                                top_k=1), rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(g.tokens), np.asarray(s.tokens))


def test_engine_sampling_seeded_and_valid():
    cfg, params, batch = _setup("yi-6b", B=2, L=5)
    gen = GenerateConfig(max_new=6, eos_id=-1, temperature=0.8, top_k=8)
    a = generate(params, batch, cfg, gen, rng=jax.random.PRNGKey(1))
    b = generate(params, batch, cfg, gen, rng=jax.random.PRNGKey(1))
    c = generate(params, batch, cfg, gen, rng=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert (np.asarray(a.tokens) != np.asarray(c.tokens)).any()
    assert (np.asarray(a.tokens) >= 0).all()
    assert (np.asarray(a.tokens) < cfg.vocab).all()


# ---------------------------------------------------------------------------
# beam search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-6b", "zcode-m3-base", "mamba2-1.3b"])
def test_engine_beam1_equals_greedy(arch):
    cfg, params, batch = _setup(arch, B=2, L=5)
    g = generate(params, batch, cfg, GenerateConfig(max_new=6, eos_id=-1))
    b1 = generate(params, batch, cfg,
                  GenerateConfig(max_new=6, eos_id=-1, beam_width=1))
    np.testing.assert_array_equal(np.asarray(g.tokens), np.asarray(b1.tokens))


@pytest.mark.parametrize("arch", ["yi-6b", "zcode-m3-base"])
def test_engine_beam_improves_score(arch):
    """Beam-4 total log-probability >= greedy total log-probability
    (eos disabled so all hypotheses have equal length; penalty 0 makes
    scores directly comparable sums)."""
    cfg, params, batch = _setup(arch, B=2, L=5)
    g = generate(params, batch, cfg, GenerateConfig(max_new=8, eos_id=-1))
    b = generate(params, batch, cfg,
                 GenerateConfig(max_new=8, eos_id=-1, beam_width=4,
                                length_penalty=0.0))
    assert (np.asarray(b.scores) >= np.asarray(g.scores) - 1e-4).all()


# ---------------------------------------------------------------------------
# backend threading
# ---------------------------------------------------------------------------

def test_engine_decodes_through_pallas_backend():
    """--backend pallas keeps working through the engine (DESIGN.md §6):
    the MoE layers of an enc-dec MoE arch execute via the kernel pipeline
    (interpret mode on CPU) inside the compiled loop."""
    import dataclasses
    cfg = reduced(get_config("zcode-m3-base"))
    greedy = GenerateConfig(max_new=4, eos_id=-1)
    params = init_model(KEY, cfg)
    batch = make_batch(cfg, KEY, 1, 4)
    ref = generate(params, batch, cfg, greedy)
    cfgp = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, backend="pallas"))
    res = generate(params, batch, cfgp, greedy)
    assert isinstance(res, GenerateResult)
    # same routing + same weights -> same greedy tokens within kernel numerics
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(ref.tokens))


def test_engine_decodes_through_fused_backend():
    """--backend pallas_fused: the ONE-launch megakernel (DESIGN.md §11)
    drives the MoE layers inside the compiled decode loop."""
    import dataclasses
    cfg = reduced(get_config("zcode-m3-base"))
    greedy = GenerateConfig(max_new=4, eos_id=-1)
    params = init_model(KEY, cfg)
    batch = make_batch(cfg, KEY, 1, 4)
    ref = generate(params, batch, cfg, greedy)
    cfgf = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, backend="pallas_fused"))
    res = generate(params, batch, cfgf, greedy)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(ref.tokens))


def test_flash_decode_pool_parity_ragged_positions():
    """``flash_decode=True`` pool decode == reference attention, token for
    token, with every slot at its OWN depth and one slot EOS-retired
    (DESIGN.md §9/§11): the flash kernel's per-row index masking must
    reproduce the reference per-row validity exactly."""
    from repro.serve import decode_pool_step, prefill_into_slots
    from repro.serve.engine import slot_pool_like
    cfg, params, batch = _setup("zcode-m3-base", B=3, L=8)
    max_seq = 16
    lengths = jnp.array([3, 8, 5], jnp.int32)     # ragged true prompt lens
    pool0 = slot_pool_like(params, batch, cfg, max_seq=max_seq, n_slots=3)
    logits, pool0 = prefill_into_slots(params, batch, lengths,
                                       jnp.arange(3), pool0, cfg,
                                       max_seq=max_seq)
    tok = logits.argmax(-1).astype(jnp.int32)
    alive = jnp.array([True, False, True])        # slot 1 retired (EOS)
    # structural: the flash step actually launches the Pallas kernel
    jx = str(jax.make_jaxpr(
        lambda p, c, t, ps, a: decode_pool_step(
            p, c, t, ps, a, cfg, flash_decode=True))(
        params, pool0, tok, lengths, alive))
    assert "pallas_call" in jx
    pools = {False: pool0, True: pool0}
    toks = {False: tok, True: tok}
    pos = lengths
    for _ in range(3):
        step = {}
        for fl in (False, True):
            lg, pools[fl] = decode_pool_step(params, pools[fl], toks[fl],
                                             pos, alive, cfg,
                                             flash_decode=fl)
            step[fl] = lg
            toks[fl] = lg.argmax(-1).astype(jnp.int32)
        np.testing.assert_allclose(np.asarray(step[True]),
                                   np.asarray(step[False]), atol=3e-4)
        np.testing.assert_array_equal(np.asarray(toks[True]),
                                      np.asarray(toks[False]))
        pos = pos + 1
