"""Optimizer / data pipeline / checkpoint / metrics tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import TrainConfig
from repro.data import (EOS, MTTaskConfig, MultilingualMT, LMTaskConfig,
                        SyntheticLM, PAD)
from repro.metrics import corpus_bleu, strip_special
from repro.optim import adam_init, adam_update, schedule


# ---------------------------------------------------------------- optimizer

def test_adam_first_step_is_lr_signed():
    tc = TrainConfig(lr=0.1, warmup_steps=1, schedule="constant",
                     grad_clip=0.0, eps=1e-12)
    params = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, -0.25])}
    opt = adam_init(params, tc)
    new_p, opt, m = adam_update(g, opt, params, tc)
    # bias-corrected first step: delta = lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray([1.0 - 0.1, -2.0 + 0.1]), rtol=1e-5)


def test_adam_converges_on_quadratic():
    tc = TrainConfig(lr=0.1, warmup_steps=1, schedule="constant",
                     grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adam_init(params, tc)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adam_update(g, opt, params, tc)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_caps_norm():
    tc = TrainConfig(lr=1.0, warmup_steps=1, schedule="constant",
                     grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    opt = adam_init(params, tc)
    _, _, m = adam_update(g, opt, params, tc)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_inverse_sqrt_schedule():
    tc = TrainConfig(lr=0.03, warmup_steps=5000, schedule="inverse_sqrt")
    s = lambda t: float(schedule(jnp.asarray(t), tc))
    assert s(2500) == pytest.approx(0.015, rel=1e-3)       # linear warmup
    assert s(5000) == pytest.approx(0.03, rel=1e-3)        # peak
    assert s(20000) == pytest.approx(0.015, rel=1e-3)      # 1/sqrt decay
    assert s(1) < s(100) < s(5000)


def test_bf16_moments_supported():
    tc = TrainConfig(moment_dtype="bfloat16", schedule="constant")
    params = {"w": jnp.ones(8)}
    opt = adam_init(params, tc)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    p2, opt2, _ = adam_update({"w": jnp.ones(8)}, opt, params, tc)
    assert opt2["v"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == params["w"].dtype


# ---------------------------------------------------------------- data

def test_mt_deterministic_and_shards_disjoint():
    task = MultilingualMT(MTTaskConfig(vocab=256, n_langs=4))
    a = task.sample_batch(3, 16)
    b = task.sample_batch(3, 16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    s0 = task.sample_batch(3, 16, shard=0, n_shards=2)
    s1 = task.sample_batch(3, 16, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 8
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_mt_translation_is_lang_permutation_reversed():
    task = MultilingualMT(MTTaskConfig(vocab=256, n_langs=4))
    b = task.sample_batch(0, 8, lang=1)
    for i in range(8):
        enc = b["enc_tokens"][i]
        assert enc[0] == task.lang_tag(1)
        src = enc[1:list(enc).index(EOS)] - task.first_content
        expect = task.translate(src, 1) + task.first_content
        n = int(b["loss_mask"][i].sum()) - 1   # minus EOS slot
        np.testing.assert_array_equal(b["labels"][i][:n], expect[:n])


def test_mt_low_resource_sampling():
    cfg = MTTaskConfig(vocab=256, n_langs=8, low_resource_weight=0.05)
    task = MultilingualMT(cfg)
    langs = np.concatenate([task.sample_batch(s, 64)["lang"]
                            for s in range(30)])
    low = np.isin(langs, task.low_langs).mean()
    assert low < 0.15      # low-resource languages are rare


def test_lm_task_learnable_structure():
    task = SyntheticLM(LMTaskConfig(vocab=128, seq_len=32))
    b = task.sample_batch(0, 8)
    assert b["tokens"].shape == (8, 32)
    # ~90% of transitions follow the chain
    t, l = b["tokens"], b["labels"]
    follow = (l == (task.a * t + task.b) % (128 - 3) + 3).mean()
    assert follow > 0.75


# ---------------------------------------------------------------- metrics

def test_bleu_perfect_and_zero():
    refs = [[3, 4, 5, 6, 7, 8]] * 4
    assert corpus_bleu(refs, refs) == pytest.approx(100.0, abs=1e-6)
    assert corpus_bleu([[9, 10, 11, 12, 13, 14]] * 4, refs) < 1.0


def test_strip_special():
    assert strip_special([5, 6, 2, 7, 0]) == [5, 6]
    assert strip_special([0, 5, 0, 6]) == [5, 6]


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros(2), jnp.full((2, 2), 7)]},
            "step": jnp.asarray(5, jnp.int32)}
    d = save_checkpoint(str(tmp_path), 42, tree, {"note": "x"})
    assert os.path.exists(os.path.join(d, "arrays.npz"))
    template = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    restored, meta = restore_checkpoint(str(tmp_path), template)
    assert meta["step"] == 42 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_pointer(tmp_path):
    tree = {"w": jnp.ones(3)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, {"w": jnp.full(3, 2.0)})
    restored, meta = restore_checkpoint(str(tmp_path),
                                        {"w": jnp.zeros(3)})
    assert meta["step"] == 2
    assert float(restored["w"][0]) == 2.0
