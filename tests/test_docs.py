"""Docs stay honest: every `DESIGN.md §N` reference in src/ must resolve
to a real section, and the README's verify command must match ROADMAP.md."""
import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(*parts):
    with open(os.path.join(REPO, *parts)) as f:
        return f.read()


def _design_sections():
    text = _read("DESIGN.md")
    return set(re.findall(r"^##\s*§(\d+)", text, flags=re.M))


def test_design_md_exists_with_required_sections():
    secs = _design_sections()
    # §2 consensus PRNG, §4 mesh layout, §5 strategies, §6 backend
    # registry, §7 decoding engine
    assert {"2", "4", "5", "6", "7"} <= secs, secs


def test_serve_engine_cites_design():
    """The decoding engine must carry its DESIGN.md §7 contract references
    (cache indexing, early exit, beam bookkeeping)."""
    text = _read("src", "repro", "serve", "engine.py")
    assert "DESIGN.md §7" in text


def test_every_design_reference_in_src_resolves():
    secs = _design_sections()
    missing = []
    for root, _, files in os.walk(os.path.join(REPO, "src")):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(root, fn)
            with open(path) as f:
                for i, line in enumerate(f, 1):
                    for ref in re.findall(r"DESIGN(?:\.md)?\s*§(\d+)", line):
                        if ref not in secs:
                            missing.append(f"{path}:{i} §{ref}")
    assert not missing, f"dangling DESIGN.md references: {missing}"


def test_readme_has_tier1_command():
    readme = _read("README.md")
    assert "PYTHONPATH=src" in readme and "pytest" in readme


def test_requirements_cover_test_imports():
    reqs = _read("requirements.txt").lower()
    for pkg in ("jax", "numpy", "pytest"):
        assert pkg in reqs, pkg
    # the suite must not depend on anything outside requirements.txt
    assert "hypothesis" not in reqs
