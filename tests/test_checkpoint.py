"""Checkpointing: save -> restore must be bitwise (bf16 leaves included),
and a restored train state must continue EXACTLY like the uninterrupted
run — same params, same Gating-Dropout consensus stream (DESIGN.md §2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import (GatingDropoutConfig, ModelConfig, MoEConfig,
                                TrainConfig)
from repro.core.gating_dropout import drop_decision_host
from repro.data import LMTaskConfig, SyntheticLM
from repro.models import init_model
from repro.training import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(**kw):
    return ModelConfig(d_model=32, d_ff=64, vocab=64, n_layers=2, n_heads=2,
                       n_kv_heads=2, remat=False, dtype="float32",
                       param_dtype="float32", **kw)


def test_roundtrip_bitwise_with_bf16(tmp_path):
    """Mixed-dtype pytree (f32 / bf16 / int32 / nested dict+list) survives
    save->restore bit-for-bit. bf16 leaves go through the uint16 bit-pattern
    path in checkpoint.py."""
    tree = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7,
                   "b16": (jnp.arange(8, dtype=jnp.float32) / 3
                           ).astype(jnp.bfloat16)},
        "opt": [jnp.ones((2, 2), jnp.float32) * np.pi,
                jnp.full((3,), -1.5, jnp.bfloat16)],
        "step": jnp.asarray(17, jnp.int32),
    }
    save_checkpoint(str(tmp_path), 17, tree)
    assert latest_step(str(tmp_path)) == 17
    restored, meta = restore_checkpoint(str(tmp_path), tree)
    assert meta["step"] == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        # bitwise: compare the raw bit patterns, not values-within-tolerance
        av = np.asarray(a.view(jnp.uint16) if a.dtype == jnp.bfloat16 else a)
        bv = np.asarray(b.view(jnp.uint16) if b.dtype == jnp.bfloat16 else b)
        np.testing.assert_array_equal(av, bv)


def test_roundtrip_model_train_state(tmp_path):
    cfg = _tiny_cfg()
    tc = TrainConfig(lr=1e-3, warmup_steps=2)
    state = init_train_state(init_model(KEY, cfg), tc)
    save_checkpoint(str(tmp_path), 0, state, {"arch": cfg.arch_id})
    restored, meta = restore_checkpoint(str(tmp_path), state)
    assert meta["arch"] == cfg.arch_id
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_continues_identically(tmp_path):
    """4 straight steps == 2 steps -> checkpoint -> restore -> 2 more, with
    the batch stream and the (seed, step) consensus PRNG keyed by the
    ABSOLUTE step — the exact contract behind launch/train.py --resume."""
    cfg = _tiny_cfg(moe=MoEConfig(
        n_experts=4, top_k=1, d_ff_expert=64, jitter_eps=0.0,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.5)))
    tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3)
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
    gd = cfg.moe.gating_dropout
    step = make_train_step(cfg, tc)   # jitted: one executable per decision

    def batch(i):
        return {k: jnp.asarray(v) for k, v in task.sample_batch(i, 4).items()}

    def run(state, lo, hi):
        for i in range(lo, hi):
            state, _ = step(state, batch(i),
                            drop_decision_host(gd, tc.seed, i))
        return state

    s_straight = run(init_train_state(init_model(KEY, cfg), tc), 0, 4)

    s = run(init_train_state(init_model(KEY, cfg), tc), 0, 2)
    save_checkpoint(str(tmp_path), 2, s)
    template = init_train_state(init_model(KEY, cfg), tc)
    s_resumed, meta = restore_checkpoint(str(tmp_path), template)
    assert meta["step"] == 2
    assert int(s_resumed["step"]) == 2       # in-graph PRNG fold continues
    s_resumed = run(s_resumed, 2, 4)

    # the dropped/routed pattern over steps 0..3 is nontrivial at rate 0.5
    assert any(drop_decision_host(gd, tc.seed, i) for i in range(8))
    for a, b in zip(jax.tree.leaves(s_straight), jax.tree.leaves(s_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("strategy", ["traced_cond", "host_cond"])
def test_trainer_resume_continues_identically(tmp_path, strategy):
    """The Trainer's --resume contract (DESIGN.md §8): 6 straight scan-fused
    steps == 4 steps -> checkpoint -> restore -> 2 more, BITWISE. Both the
    data stream (batch_fn keyed by absolute step) and the Gating-Dropout
    consensus stream ((seed, step) fold) must continue where the
    checkpointed run left off — even though the resumed run chunks the
    remaining steps differently."""
    from repro.training import Trainer
    cfg = _tiny_cfg(moe=MoEConfig(
        n_experts=4, top_k=1, d_ff_expert=64, jitter_eps=0.0,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.5)))
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
    batch_fn = lambda i: task.sample_batch(i, 4)   # noqa: E731

    def make(steps, ckpt=None):
        tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3, steps=steps)
        return Trainer(cfg, tc, batch_fn, chunk=3, strategy=strategy,
                       ckpt_dir=ckpt, log=None)

    s_straight, _ = make(6).run()

    make(4, ckpt=str(tmp_path)).run()              # saves at step 4
    tr = make(6, ckpt=str(tmp_path))
    assert tr.restore() == 4
    assert int(tr.state["step"]) == 4
    s_resumed, _ = tr.run()

    gd = cfg.moe.gating_dropout
    assert any(drop_decision_host(gd, 3, i) for i in range(6))
    for a, b in zip(jax.tree.leaves(s_straight), jax.tree.leaves(s_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
