"""Communication substrate (DESIGN.md §10): registry + quantization +
topology algebra + telemetry/cost-model/HLO agreement.

The contract, by substrate:
  dense        -- BITWISE the pre-refactor inline all-to-all pair;
  hierarchical -- same permutation as dense (bitwise), two factored hops;
  compressed   -- forward within int8/fp8 tolerance of dense, gradients
                  flow through the quantize custom VJP;
and for all of them: the in-graph telemetry equals the analytic model
(`comm/cost.py`) equals the collective ops parsed from compiled HLO on
the sharded path, equals ZERO on Gate-Drop local / expert-drop steps,
and the host_cond dropped executable still contains zero all-to-alls.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.comm import (available_substrates, dequantize, ep_tier_groups,
                        factored_ep, format_table, get_substrate, layer_cost,
                        quantize, substrate_table, transport_cost)
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig, TrainConfig)
from repro.core import get_backend, init_moe_params
from repro.core.moe import moe_oracle

KEY = jax.random.PRNGKey(0)


def _cfg(comm=CommConfig(), mode="gate_drop", E=8, k=2):
    return ModelConfig(
        d_model=32, d_ff=64, vocab=64, dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=64, jitter_eps=0.0,
                      comm=comm,
                      gating_dropout=GatingDropoutConfig(mode=mode,
                                                         rate=0.3)))


def _xp(cfg, shape=(8, 16, 32)):
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    return p, x


# ---------------------------------------------------------------- registry

def test_registry_contents_and_errors():
    from repro.configs.base import COMM_SUBSTRATES
    assert set(available_substrates()) == set(COMM_SUBSTRATES) == {
        "dense", "hierarchical", "compressed", "hierarchical_compressed",
        "overlapped", "overlapped_hierarchical", "overlapped_compressed",
        "overlapped_hierarchical_compressed"}
    with pytest.raises(KeyError, match="unknown comm substrate"):
        get_substrate("nope")
    with pytest.raises(AssertionError):
        CommConfig(substrate="nope")
    with pytest.raises(AssertionError):
        CommConfig(quant="int4")
    with pytest.raises(AssertionError):
        CommConfig(n_chunks=0)
    c = CommConfig(substrate="hierarchical_compressed")
    assert c.hierarchical and c.compressed and not c.overlapped
    assert not CommConfig().hierarchical and not CommConfig().compressed
    o = CommConfig(substrate="overlapped_hierarchical_compressed")
    assert o.overlapped and o.hierarchical and o.compressed
    assert CommConfig(substrate="overlapped").overlapped
    assert not CommConfig(substrate="overlapped").hierarchical
    assert not CommConfig(substrate="overlapped").compressed


def test_factored_ep_and_tier_groups():
    assert factored_ep(16, 0) == (4, 4)
    assert factored_ep(8, 0) == (2, 4)
    assert factored_ep(8, 4) == (4, 2)
    assert factored_ep(1, 0) == (1, 1)
    with pytest.raises(AssertionError):
        factored_ep(8, 3)
    intra, inter = ep_tier_groups(8, 4)
    assert intra == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert inter == ((0, 4), (1, 5), (2, 6), (3, 7))
    # groups partition the ranks, both ways
    for groups in (intra, inter):
        assert sorted(r for g in groups for r in g) == list(range(8))


# ------------------------------------------------------------ quantization

@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quantize_roundtrip_bounds(mode):
    x = jax.random.normal(KEY, (4, 7, 33)) * 10.0
    q, s = quantize(x, mode)
    y = dequantize(q, s, x.dtype)
    assert q.dtype == (jnp.int8 if mode == "int8" else jnp.float8_e4m3fn)
    assert s.shape == x.shape[:-1] + (1,)
    # per-row scaled: error bounded by scale/2 (int8) / fp8 ulp
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    bound = amax / (2 * 127) if mode == "int8" else amax / 16
    assert (np.abs(np.asarray(y - x)) <= bound + 1e-7).all()
    # all-zero rows survive exactly
    q0, s0 = quantize(jnp.zeros((3, 5)), mode)
    np.testing.assert_array_equal(np.asarray(dequantize(q0, s0, x.dtype)),
                                  np.zeros((3, 5)))


# ----------------------------------------------------- oracle (virtual) path

def test_oracle_hierarchical_bitwise_dense():
    """The two-hop factored exchange is the SAME permutation as the flat
    all-to-all — virtual emulation, ep=4 (gi=2, go=2)."""
    p, x = _xp(_cfg())
    y_d, _ = moe_oracle(p, x, _cfg(), ep=4, decision=False)
    y_h, _ = moe_oracle(p, x, _cfg(CommConfig(substrate="hierarchical")),
                        ep=4, decision=False)
    np.testing.assert_array_equal(np.asarray(y_d), np.asarray(y_h))
    # explicit non-square factorization too
    y_h2, _ = moe_oracle(
        p, x, _cfg(CommConfig(substrate="hierarchical", ep_inner=4)),
        ep=4, decision=False)
    np.testing.assert_array_equal(np.asarray(y_d), np.asarray(y_h2))


@pytest.mark.parametrize("quant,tol", [("int8", 0.05), ("fp8", 0.3)])
def test_oracle_compressed_forward_parity(quant, tol):
    """Quantized wire: forward within per-row quantization tolerance of
    dense; composing with hierarchical changes NOTHING (quantize once,
    permutation in between)."""
    p, x = _xp(_cfg())
    y_d, _ = moe_oracle(p, x, _cfg(), ep=4, decision=False)
    y_c, _ = moe_oracle(
        p, x, _cfg(CommConfig(substrate="compressed", quant=quant)),
        ep=4, decision=False)
    scale = float(jnp.abs(y_d).max())
    assert float(jnp.abs(y_d - y_c).max()) < tol * scale
    y_hc, _ = moe_oracle(
        p, x, _cfg(CommConfig(substrate="hierarchical_compressed",
                              quant=quant)), ep=4, decision=False)
    np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_hc))


def test_compressed_gradient_flows_through_quantize_vjp():
    """The custom VJP (straight-through + compressed reverse wire) keeps
    the routed path trainable: gradients nonzero for EVERY param and
    close to the dense-substrate gradients."""
    p, x = _xp(_cfg())

    def loss(pp, comm):
        y, _ = moe_oracle(pp, x, _cfg(comm), ep=4, decision=False)
        return (y ** 2).sum()

    g_d = jax.grad(lambda pp: loss(pp, CommConfig()))(p)
    g_c = jax.jit(jax.grad(
        lambda pp: loss(pp, CommConfig(
            substrate="hierarchical_compressed"))))(p)
    for a, b in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_c)):
        assert float(jnp.abs(b).max()) > 0.0
        ref = float(jnp.abs(a).max())
        assert float(jnp.abs(a - b).max()) < 0.05 * ref, (ref,)


def test_pallas_ep1_matches_oracle_compressed():
    """Backend choice must not change numerics: the ep=1 kernel pipeline
    applies the same payload wire transform (roundtrip quant->dequant)
    and reports the same telemetry as the oracle."""
    cfg = _cfg(CommConfig(substrate="compressed"))
    p, x = _xp(cfg)
    y_o, aux_o = moe_oracle(p, x, cfg, ep=1, decision=False)
    y_p, aux_p = get_backend("pallas")(p, x, cfg, None, rng=None,
                                       decision=False, is_training=True,
                                       token_ids=None)
    np.testing.assert_allclose(np.asarray(y_o), np.asarray(y_p), atol=2e-6)
    for k in ("comm_a2a_calls", "comm_bytes", "comm_wire_bytes"):
        assert float(aux_o[k]) == float(aux_p[k]), k


@pytest.mark.parametrize("mode", ["gate_drop", "gate_expert_drop"])
def test_telemetry_zero_on_dropped_steps(mode):
    """Gate-Drop local / expert-drop steps move NOTHING: every comm
    counter is zero; the routed branch of the same config (ep=4 virtual
    shards) is nonzero."""
    cfg = _cfg(CommConfig(substrate="compressed"), mode=mode)
    p, x = _xp(cfg)
    _, aux_r = moe_oracle(p, x, cfg, ep=4, decision=False)
    _, aux_l = moe_oracle(p, x, cfg, ep=4, decision=True)
    assert float(aux_r["comm_a2a_calls"]) > 0
    assert float(aux_r["comm_bytes"]) > 0
    for k in ("comm_a2a_calls", "comm_bytes", "comm_wire_bytes"):
        assert float(aux_l[k]) == 0.0, (k, mode)


@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_telemetry_zero_at_ep1(backend):
    """One device = no wire: XLA deletes group-of-1 all-to-alls from the
    executable, so the counters report zero at ep=1 — telemetry always
    mirrors the compiled executable, never the nominal transport."""
    cfg = _cfg(CommConfig(substrate="compressed"))
    p, x = _xp(cfg)
    _, aux = get_backend(backend)(p, x, cfg, None, rng=None,
                                  decision=False, is_training=True,
                                  token_ids=None)
    for k in ("comm_a2a_calls", "comm_bytes", "comm_wire_bytes"):
        assert float(aux[k]) == 0.0, (k, backend)


# ------------------------------------------------------------- cost model

def test_cost_model_hand_computed():
    """transport_cost against hand-computed numbers: E=8, cap=4, d=32,
    f32 payload, ep=8 (hier auto: gi=2, go=4)."""
    E, cap, d, isz, ep = 8, 4, 32, 4, 8
    payload = E * cap * d * isz                  # 4096 B per a2a
    c = transport_cost(CommConfig(), ep=ep, n_experts=E, cap=cap,
                       d_model=d, itemsize=isz)
    assert c["calls"] == 2 and c["bytes"] == 2 * payload
    assert c["wire_bytes"] == pytest.approx(2 * payload * 7 / 8)
    assert c["intra_wire_bytes"] == 0.0          # flat = all inter-tier
    h = transport_cost(CommConfig(substrate="hierarchical"), ep=ep,
                       n_experts=E, cap=cap, d_model=d, itemsize=isz)
    assert h["calls"] == 4 and h["bytes"] == 4 * payload
    assert h["wire_bytes"] == pytest.approx(
        2 * payload * (1 / 2 + 3 / 4))           # gi=2, go=4
    assert h["inter_wire_bytes"] == pytest.approx(2 * payload * 3 / 4)
    q = transport_cost(CommConfig(substrate="compressed"), ep=ep,
                       n_experts=E, cap=cap, d_model=d, itemsize=isz)
    qbytes = E * cap * d * 1 + E * cap * 4       # int8 payload + f32 scales
    assert q["calls"] == 4 and q["bytes"] == 2 * qbytes
    # the headline claim at f32 activations: <= 0.5x dense on the wire
    assert q["wire_bytes"] <= 0.5 * c["wire_bytes"]
    hq = transport_cost(
        CommConfig(substrate="hierarchical_compressed"), ep=ep,
        n_experts=E, cap=cap, d_model=d, itemsize=isz)
    assert hq["calls"] == 8 and hq["bytes"] == 4 * qbytes
    # mesh-fixed tiers override the auto factorization
    h2 = transport_cost(CommConfig(substrate="hierarchical"), ep=ep,
                        n_experts=E, cap=cap, d_model=d, itemsize=isz,
                        tiers=(4, 2))
    assert h2["wire_bytes"] == pytest.approx(
        2 * payload * (3 / 4 + 1 / 2))
    # degenerate groups (size 1) are deleted by XLA -> not counted:
    # ep=1 moves nothing; prime ep collapses hierarchical to one hop
    c1 = transport_cost(CommConfig(), ep=1, n_experts=E, cap=cap,
                        d_model=d, itemsize=isz)
    assert c1["calls"] == 0 and c1["bytes"] == 0
    h1 = transport_cost(CommConfig(substrate="hierarchical"), ep=2,
                        n_experts=E, cap=cap, d_model=d, itemsize=isz)
    assert h1["calls"] == 2                     # gi=1 intra hop skipped


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_roundtrip_identity_every_substrate(dtype):
    """§14 round-trip property: dispatch∘combine is a pure permutation
    pair, so the transport round trip with an identity FFN body is
    BITWISE identity — for EVERY substrate x ep shape x chunk count,
    including the all-dropped (zero) buffer. Compressed substrates hold
    it on the quantizer's fixed points (one ``roundtrip`` application is
    idempotent — also asserted), so the payload is stabilized first."""
    from repro.comm.substrate import CommEnv, make_transport
    for ep, E, cap, d in ((2, 8, 4, 16), (4, 8, 6, 8), (8, 8, 4, 16)):
        x = (jax.random.normal(jax.random.PRNGKey(ep), (ep, E, cap, d))
             * 3).astype(dtype)
        for name in available_substrates():
            for n_chunks in (1, 2, cap):
                comm = CommConfig(substrate=name, n_chunks=n_chunks)
                t = make_transport(comm, CommEnv(ep=ep))
                for buf in (x, jnp.zeros_like(x)):
                    ref = t.roundtrip(buf)          # fixed-point payload
                    np.testing.assert_array_equal(
                        np.asarray(t.roundtrip(ref), np.float32),
                        np.asarray(ref, np.float32),
                        err_msg=f"roundtrip not idempotent: {name}")
                    out = t.vpipelined(ref, lambda b: b)
                    np.testing.assert_array_equal(
                        np.asarray(out, np.float32),
                        np.asarray(ref, np.float32),
                        err_msg=f"{name} ep={ep} cap={cap} n={n_chunks}")
    # the sweep leaves thousands of small chunk-shaped executables in the
    # process-wide jit cache; drop them so the rest of the suite compiles
    # against a clean CPU client (avoids late-suite compiler OOM/segfault)
    jax.clear_caches()


def test_chunked_cost_invariants_every_substrate():
    """§14 accounting regression: overlapping multiplies the a2a CALL
    count by n_eff but leaves total bytes / wire / tier split EXACTLY
    equal to the base substrate (the per-chunk payload divides evenly —
    integer arithmetic, no approx); exposed = wire/n_eff with hidden the
    remainder; non-overlapped substrates expose everything and hide
    nothing."""
    from repro.comm import effective_chunks
    E, cap, d, isz, ep = 8, 8, 32, 4, 8
    kw = dict(ep=ep, n_experts=E, cap=cap, d_model=d, itemsize=isz)
    for base in ("dense", "hierarchical", "compressed",
                 "hierarchical_compressed"):
        ov = "overlapped" if base == "dense" else f"overlapped_{base}"
        c0 = transport_cost(CommConfig(substrate=base), **kw)
        assert c0["exposed_wire_bytes"] == c0["wire_bytes"], base
        assert c0["hidden_wire_bytes"] == 0.0, base
        for n in (1, 2, 4, 8, 5):                   # 5 -> n_eff 4
            n_eff = effective_chunks(cap, n)
            cN = transport_cost(CommConfig(substrate=ov, n_chunks=n), **kw)
            assert cN["calls"] == c0["calls"] * n_eff, (ov, n)
            assert cN["bytes"] == c0["bytes"], (ov, n)
            assert cN["wire_bytes"] == c0["wire_bytes"], (ov, n)
            assert cN["intra_wire_bytes"] == c0["intra_wire_bytes"], (ov, n)
            assert cN["inter_wire_bytes"] == c0["inter_wire_bytes"], (ov, n)
            assert cN["exposed_wire_bytes"] == pytest.approx(
                cN["wire_bytes"] / n_eff), (ov, n)
            assert (cN["exposed_wire_bytes"] + cN["hidden_wire_bytes"]
                    == pytest.approx(cN["wire_bytes"])), (ov, n)
    # the chunk-count rule the transport and cost model share
    assert effective_chunks(16, 5) == 4
    assert effective_chunks(16, 16) == 16
    assert effective_chunks(16, 100) == 16          # clamped to cap
    assert effective_chunks(7, 3) == 1              # prime cap
    assert effective_chunks(6, 4) == 3


def test_transport_time_and_pipeline_time():
    """The §14 bandwidth-weighted time model: intra wire priced at the
    ICI-class rate, inter at the DCN-class rate; the two-resource FIFO
    pipeline estimate equals the hand-computed schedule."""
    from repro.comm import pipeline_time, transport_time
    from repro.configs.base import Topology
    top = Topology(intra_gbps=400.0, inter_gbps=50.0)
    E, cap, d, isz, ep = 8, 4, 32, 4, 8
    kw = dict(ep=ep, n_experts=E, cap=cap, d_model=d, itemsize=isz)
    c = transport_cost(CommConfig(substrate="hierarchical"), **kw)
    t = transport_time(c, top)
    assert t["comm_s"] == pytest.approx(
        c["intra_wire_bytes"] / 400e9 + c["inter_wire_bytes"] / 50e9)
    assert t["exposed_s"] == pytest.approx(t["comm_s"])  # non-overlapped
    cd = transport_cost(CommConfig(substrate="dense"), **kw)
    td = transport_time(cd, top)
    assert td["comm_s"] == pytest.approx(cd["wire_bytes"] / 50e9)
    # hierarchical moves MORE wire yet costs LESS time on the two-tier
    # mesh — the whole point of the factored exchange
    assert c["wire_bytes"] > cd["wire_bytes"]
    assert t["comm_s"] < td["comm_s"]
    co = transport_cost(CommConfig(substrate="overlapped", n_chunks=4),
                        **kw)
    to = transport_time(co, top)
    assert to["comm_s"] == pytest.approx(td["comm_s"])   # same wire
    assert to["exposed_s"] == pytest.approx(td["comm_s"] / 4)
    assert to["hidden_s"] == pytest.approx(3 * td["comm_s"] / 4)
    # FIFO pipeline: n=1 is fully serial; W==C at n=4 hand-computes to
    # 1.25 (vs 2.0 serial -> 1.6x); deeper never hurts; comm-bound floor
    assert pipeline_time(1.0, 1.0, 1) == pytest.approx(2.0)
    assert pipeline_time(1.0, 1.0, 4) == pytest.approx(1.25)
    assert (pipeline_time(1.0, 1.0, 8) <= pipeline_time(1.0, 1.0, 4)
            <= pipeline_time(1.0, 1.0, 2) <= 2.0)
    assert pipeline_time(0.1, 1.0, 8) >= 1.0         # can't beat the wire
    assert pipeline_time(1.0, 0.1, 8) >= 1.0         # ... or the compute


def test_substrate_table_and_dryrun_comm_table():
    """The --comm-table surface: every substrate priced, compressed
    halves the wire (plus the tiny scale overhead), hierarchical moves
    its inter-tier share below dense's all-inter wire."""
    cfg = _cfg()
    t = substrate_table(cfg, tokens_per_shard=64, ep=16, n_chunks=4)
    assert set(t) == set(available_substrates())
    dense = t["dense"]
    assert t["compressed"]["wire_bytes"] <= 0.55 * dense["wire_bytes"]
    assert (t["hierarchical"]["inter_wire_bytes"]
            < dense["inter_wire_bytes"])
    assert (t["hierarchical_compressed"]["inter_wire_bytes"]
            < t["compressed"]["inter_wire_bytes"])
    # §14 columns: overlapped rows expose wire/n_eff of identical totals
    # and carry a strictly smaller exposed-time estimate
    ov = t["overlapped"]
    assert ov["wire_bytes"] == dense["wire_bytes"]
    assert ov["exposed_wire_bytes"] < dense["exposed_wire_bytes"]
    assert ov["t_comm_s"] == pytest.approx(dense["t_comm_s"])
    assert ov["t_exposed_s"] < dense["t_exposed_s"]
    txt = format_table(t)
    for name in t:
        assert name in txt
    # the launch surface is pure math over the same model
    from repro.launch.dryrun import comm_table
    tbl = comm_table("zcode-m3-base", "train_4k")
    assert set(tbl) == set(available_substrates())
    assert tbl["compressed"]["wire_bytes"] < tbl["dense"]["wire_bytes"]


def test_total_loss_surfaces_comm_metrics():
    """training metrics carry the §10 counters, consistent with
    layer_cost x n_moe_layers (ep=1 in-process: both sides zero — the op
    is absent from the executable; the nonzero multi-device metric
    stream is asserted end-to-end in the subprocess Trainer test)."""
    from conftest import train_batch
    from repro.models import init_model
    from repro.training.steps import n_moe_layers, total_loss
    cfg = dataclasses.replace(
        _cfg(CommConfig(substrate="compressed")), n_layers=2, n_heads=2,
        n_kv_heads=2, remat=False, param_dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    b = train_batch(cfg, jax.random.PRNGKey(1), B=2, L=16)
    _, m_routed = total_loss(params, b, cfg, None, rng=None, decision=False)
    per_layer = layer_cost(cfg, tokens_per_shard=2 * 16, ep=1)
    for k, ck in (("comm_bytes", "bytes"), ("comm_a2a_calls", "calls"),
                  ("comm_wire_bytes", "wire_bytes")):
        assert float(m_routed[k]) == pytest.approx(
            per_layer[ck] * n_moe_layers(cfg)), k


# ------------------------------------------------------- sharded (real mesh)

def test_sharded_substrates_structural():
    """THE sharded-path contract on a real 8-device mesh, all substrates:

    * dense is BITWISE the pre-refactor inline all_to_all pair;
    * hierarchical is BITWISE dense (axis_index_groups two-hop);
    * compressed matches dense within quantization tolerance and matches
      the oracle emulation to f32 noise;
    * telemetry == cost model == compiled-HLO collective count/bytes/wire
      for every substrate."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig)
from repro.comm import layer_cost
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.core import router as R
from repro.core.moe import _expert_ffn, _shard_map, moe_oracle
from repro.analysis import parse_collectives
from repro.launch.mesh import make_mesh

def cfg_with(comm):
    return ModelConfig(d_model=32, d_ff=64, vocab=64, dtype='float32',
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, jitter_eps=0.0,
                      comm=comm, backend='sharded',
                      gating_dropout=GatingDropoutConfig(mode='gate_drop',
                                                         rate=0.3)))

ctx = ParallelContext(mesh=make_mesh((8,), ('data',)))
p = init_moe_params(jax.random.PRNGKey(0), cfg_with(CommConfig()))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
ys = {}
for name in ('dense', 'hierarchical', 'compressed',
             'hierarchical_compressed', 'overlapped',
             'overlapped_hierarchical_compressed'):
    comm = CommConfig(substrate=name, n_chunks=2)
    cfg = cfg_with(comm)
    f = jax.jit(lambda p_, x_: moe_sharded(p_, x_, cfg, ctx, rng=None,
                                           decision=False))
    colls = parse_collectives(f.lower(p, x).compile().as_text()
                              )['all-to-all']
    y, aux = f(p, x)
    ys[name] = np.asarray(y)
    c = layer_cost(cfg, tokens_per_shard=16, ep=8)
    assert float(aux['comm_a2a_calls']) == colls['count'] == c['calls'], name
    assert float(aux['comm_bytes']) == colls['bytes'] == c['bytes'], name
    assert abs(float(aux['comm_wire_bytes']) - colls['wire_bytes']) < 1, name
    assert abs(float(aux['comm_wire_bytes']) - c['wire_bytes']) < 1, name
    assert (float(aux['comm_exposed_bytes'] + aux['comm_hidden_bytes'])
            == float(aux['comm_wire_bytes'])), name

assert np.array_equal(ys['dense'], ys['hierarchical'])
assert np.array_equal(ys['compressed'], ys['hierarchical_compressed'])
# §14: the micro-chunked pipeline is BITWISE its base substrate — and the
# unrolled per-chunk collectives really are distinct HLO ops (2 hops x 2
# chunks for overlapped vs dense's 2; x2 again for the factored hops)
assert np.array_equal(ys['dense'], ys['overlapped'])
assert np.array_equal(ys['compressed'],
                      ys['overlapped_hierarchical_compressed'])
scale = np.abs(ys['dense']).max()
assert np.abs(ys['dense'] - ys['compressed']).max() < 0.05 * scale

# oracle emulation == sharded, for the quantized wire too
cfgc = cfg_with(CommConfig(substrate='compressed'))
y_o, _ = moe_oracle(p, x, cfgc, ep=8, decision=False)
assert np.abs(np.asarray(y_o) - ys['compressed']).max() < 1e-5

# pre-refactor reference: the exact inline code _routed_shard used to have
cfg = cfg_with(CommConfig())
moe = cfg.moe
def legacy(wr, experts, x_loc):
    B, L, d = x_loc.shape
    xf = x_loc.reshape(B * L, d)
    T, E = xf.shape[0], moe.n_experts
    cap = min(R.capacity(T, E, moe.top_k, moe.capacity_factor), T)
    rr = R.route(wr, xf, moe, rng=None, is_training=True, token_ids=None)
    info = R.dispatch_info(rr, E, cap)
    buf = R.dispatch(xf, info, E, cap)
    buf = jax.lax.all_to_all(buf, 'data', split_axis=0, concat_axis=1,
                             tiled=True)
    out = _expert_ffn(experts, buf, cfg, None)
    out = jax.lax.all_to_all(out, 'data', split_axis=1, concat_axis=0,
                             tiled=True)
    return R.combine(out, info).reshape(B, L, d)
espec = {'w_in': P('data', None, None), 'w_out': P('data', None, None),
         'w_gate': P('data', None, None)}
fn = _shard_map(legacy, ctx.mesh, (P(), espec, P('data', None, None)),
                P('data', None, None))
y_legacy = np.asarray(fn(p['router']['w'], p['experts'], x))
assert np.array_equal(y_legacy, ys['dense']), 'dense != pre-refactor inline'
print('OK')
""")
    assert "OK" in out


def test_sharded_hierarchical_ep_on_model():
    """Two-mesh-axes tiers: with ep_on_model the ep group IS
    (data x model); the hierarchical substrate hops over `model` (intra)
    then `data` (inter) — still bitwise the flat tuple-axis a2a."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig)
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.launch.mesh import make_mesh

def cfg_with(comm):
    return ModelConfig(d_model=32, d_ff=64, vocab=64, dtype='float32',
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=64, jitter_eps=0.0,
                      ep_on_model=True, comm=comm, backend='sharded'))

ctx = ParallelContext(mesh=make_mesh((4, 2), ('data', 'model')))
p = init_moe_params(jax.random.PRNGKey(0), cfg_with(CommConfig()))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
ys = {}
for name in ('dense', 'hierarchical'):
    cfg = cfg_with(CommConfig(substrate=name))
    y, aux = jax.jit(lambda p_, x_: moe_sharded(p_, x_, cfg, ctx, rng=None,
                                                decision=False))(p, x)
    ys[name] = np.asarray(y)
    assert float(aux['comm_a2a_calls']) == (2 if name == 'dense' else 4)
assert np.array_equal(ys['dense'], ys['hierarchical'])
print('OK')
""")
    assert "OK" in out


def test_dropped_chunk_no_a2a_and_trainer_telemetry():
    """The §5/§8 structural claim survives EVERY wire: a host_cond
    dropped chunk executable contains zero all-to-alls even when the
    routed branch would use the maximal substrate composition
    (hierarchical + compressed); the routed one contains them. And the
    Trainer's per-step history records carry the in-graph counters on a
    REAL 8-device mesh: routed steps report the full per-step wire,
    dropped steps zero — exactly following the host-drawn decisions."""
    out = run_py("""
import json
import jax, jax.numpy as jnp
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig, TrainConfig)
from repro.core.gating_dropout import drop_decision_host
from repro.core.moe import ParallelContext
from repro.data import LMTaskConfig, SyntheticLM, stack_batches
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.training import Trainer, init_train_state, make_chunk_step
ctx = ParallelContext(mesh=make_mesh((8,), ('data',)))
cfg = ModelConfig(d_model=64, d_ff=128, vocab=100, n_layers=1, n_heads=2,
                  n_kv_heads=2, remat=False, dtype='float32',
                  param_dtype='float32',
                  moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                                backend='sharded',
                                comm=CommConfig(
                                    substrate='hierarchical_compressed'),
                                gating_dropout=GatingDropoutConfig(
                                    mode='gate_drop', rate=0.5,
                                    strategy='host_cond')))
tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3, steps=6)
task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
batches = {k: jnp.asarray(v) for k, v in
           stack_batches(lambda i: task.sample_batch(i, 8), 0, 2).items()}
state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
chunk = make_chunk_step(cfg, tc, ctx, jit=False)
for dec, name in [(False, 'routed'), (True, 'dropped')]:
    txt = jax.jit(chunk, static_argnums=(2,)).lower(
        state, batches, dec).compile().as_text()
    print(name, txt.count('all-to-all'))
tr = Trainer(cfg, tc, lambda i: task.sample_batch(i, 8), ctx=ctx, chunk=3,
             strategy='host_cond', log=None, log_every=1)
_, hist = tr.run()
gd = cfg.moe.gating_dropout
wire = [r['comm_wire_bytes'] for r in hist]
assert any(w > 0 for w in wire) and any(w == 0 for w in wire), wire
for r in hist:
    dropped = drop_decision_host(gd, tc.seed, r['step'])
    assert (r['comm_wire_bytes'] == 0) == dropped, r
    assert (r['comm_a2a_calls'] == 0) == dropped, r
print('trainer_ok', 1)
""")
    lines = dict(line.split() for line in out.strip().splitlines())
    assert int(lines["routed"]) > 0
    assert int(lines["dropped"]) == 0
    assert int(lines["trainer_ok"]) == 1


# ----------------------------------------------------------------- serving

def test_scheduler_tick_log_prices_the_trace():
    """The scheduler records every device call; the serve CLI's comm
    section prices them with the cost model — local_routing decode ticks
    cost zero on the wire."""
    from repro.launch.serve import trace_comm_section
    from repro.models import init_model
    from repro.serve import ContinuousScheduler, GenerateConfig, Request
    cfg = dataclasses.replace(
        _cfg(CommConfig(substrate="compressed"), k=1), n_layers=2,
        n_heads=2, n_kv_heads=2, remat=False, param_dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new=4, eos_id=-1)
    reqs = [Request(rid=i, tokens=np.full(4 + i, 3, np.int32), arrival=0.0)
            for i in range(2)]
    sched = ContinuousScheduler(params, cfg, gen, n_slots=2,
                                prefill_buckets=(8,))
    sched.run(reqs)
    kinds = {k for k, _ in sched.tick_log}
    assert kinds == {"prefill", "decode"}
    assert len(sched.tick_log) >= sched.stats["decode_steps"]
    sec = trace_comm_section(cfg, gen, sched, ep=8)
    assert sec["substrate"] == "compressed"
    assert sec["wire_bytes_total"] > 0
    assert sec["n_ticks"] == len(sched.tick_log)
    assert set(sec["wire_bytes_per_tick"]) == {50, 90, 99}
    # local routing: decode moves nothing; only prefills are priced
    gen_l = dataclasses.replace(gen, local_routing=True)
    sec_l = trace_comm_section(cfg, gen_l, sched, ep=8)
    assert sec_l["wire_bytes_total"] < sec["wire_bytes_total"]
