"""Observability layer tests (repro.obs, DESIGN.md §15).

Anchors: the exported trace is valid Chrome trace-event JSON with correct
span nesting and per-thread tracks; a DISABLED tracer records nothing and
allocates nothing per call; the in-graph MetricsFrame changes not one bit
of the train-state stream when toggled (telemetry only); registry
percentiles match np.percentile exactly and never raise on empty data;
the schedulers' tick_log/alive_log stay exact live views over the
registry. This module runs under the conftest host-transfer guard, so
every instrumented path exercised here is also proven free of hidden
device->host syncs.
"""
import dataclasses
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (GatingDropoutConfig, ModelConfig, MoEConfig,
                                TrainConfig)
from repro.data import LMTaskConfig, SyntheticLM
from repro.models import init_model
from repro.obs import (FRAME_KEYS, MetricsFrame, MetricsRegistry, Tracer,
                       load_imbalance, monotonic, router_health)
from repro.serve import ContinuousScheduler, GenerateConfig, Request
from repro.training import Trainer, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _cfg(moe=True, rate=0.5):
    kw = {}
    if moe:
        kw["moe"] = MoEConfig(n_experts=4, top_k=1, d_ff_expert=64,
                              jitter_eps=0.0,
                              gating_dropout=GatingDropoutConfig(
                                  mode="gate_drop", rate=rate))
    return ModelConfig(d_model=32, d_ff=64, vocab=64, n_layers=2, n_heads=2,
                       n_kv_heads=2, remat=False, dtype="float32",
                       param_dtype="float32", **kw)


# ---------------------------------------------------------------------------
# tracer: spans, nesting, export schema
# ---------------------------------------------------------------------------

def test_span_nesting_and_export_schema(tmp_path):
    """Nested spans + instants + a worker-thread event export to valid
    Chrome trace-event JSON: X events with µs ts/dur, containment of the
    inner slice, 's':'t' instants, per-thread thread_name metadata."""
    tr = Tracer(enabled=True)
    with tr.span("outer", step=3):
        with tr.span("inner", kind="fetch"):
            tr.instant("mark", hit=True)
    t = threading.Thread(target=lambda: tr.instant("from_worker"),
                         name="worker")
    t.start()
    t.join()
    tr.counter("alive", slots=2)

    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())          # round-trips from disk
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}

    meta = [e for e in evs if e["ph"] == "M"]
    assert {"repro", "MainThread", "worker"} <= {
        e["args"]["name"] for e in meta}

    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == inner["ph"] == "X"
    assert outer["args"] == {"step": 3}
    # µs since the tracer epoch; the inner slice nests inside the outer
    assert 0 <= outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert by_name["mark"]["s"] == "t"
    assert by_name["mark"]["args"] == {"hit": True}
    assert by_name["alive"]["ph"] == "C"
    # the worker-thread instant landed on its own dense track
    assert by_name["from_worker"]["tid"] != by_name["outer"]["tid"]


def test_tracer_args_jsonable():
    """Non-primitive span args are stringified, never break export."""
    tr = Tracer(enabled=True)
    with tr.span("s", shape=(2, 3), obj=object()):
        pass
    doc = tr.export()
    args = [e for e in doc["traceEvents"] if e["name"] == "s"][0]["args"]
    assert args["shape"] == "(2, 3)"
    assert isinstance(args["obj"], str)
    json.dumps(doc)


def test_disabled_tracer_costs_nothing():
    """The disabled fast path: one shared no-op context manager (no
    per-call allocation), zero events, and 100k instrumented no-op blocks
    complete in well under a second."""
    tr = Tracer(enabled=False)
    assert tr.span("a", x=1) is tr.span("b")    # shared _NULL, no alloc
    t0 = monotonic()
    for i in range(100_000):
        with tr.span("chunk", step=i):
            pass
        tr.instant("mark")
    dt = monotonic() - t0
    assert len(tr) == 0
    evs = tr.export()["traceEvents"]            # only process metadata
    assert [e["name"] for e in evs] == ["process_name"]
    assert dt < 1.0, f"disabled tracer overhead {dt:.3f}s for 100k spans"


# ---------------------------------------------------------------------------
# MetricsFrame: bitwise non-interference + host-side math
# ---------------------------------------------------------------------------

def test_metrics_frame_bitwise_non_interference():
    """metrics_frame on vs off from identical init: the train-state
    stream and the loss/acc metrics are BITWISE identical — the switch
    only adds/removes telemetry keys."""
    cfg = _cfg()
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
    states, metrics = {}, {}
    for frame in (False, True):
        tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3,
                         metrics_frame=frame)
        step = make_train_step(cfg, tc)
        s = init_train_state(init_model(jax.random.PRNGKey(tc.seed), cfg),
                             tc)
        for i in range(3):
            b = {k: jnp.asarray(v)
                 for k, v in task.sample_batch(i, 4).items()}
            s, ms = step(s, b, None)
        states[frame], metrics[frame] = s, jax.device_get(ms)
    for a, b in zip(jax.tree.leaves(states[False]),
                    jax.tree.leaves(states[True])):
        np.testing.assert_array_equal(np.asarray(jax.device_get(a)),
                                      np.asarray(jax.device_get(b)))
    np.testing.assert_array_equal(metrics[False]["loss"],
                                  metrics[True]["loss"])
    extra = set(metrics[True]) - set(metrics[False])
    assert extra and extra <= set(FRAME_KEYS)
    assert "router_entropy" in extra and "expert_load" in extra


def test_metrics_frame_typed_view():
    """from_metrics builds only from a complete frame; imbalance and
    summary math behave on known inputs."""
    assert MetricsFrame.from_metrics({"loss": np.zeros(2)}) is None
    K, E = 4, 4
    ms = {k: np.zeros(K) for k in FRAME_KEYS}
    ms["expert_load"] = np.tile(np.asarray([1.0, 0.0, 0.0, 0.0]), (K, 1))
    ms["router_entropy"] = np.full(K, 0.7)
    ms["gate_dropped"] = np.asarray([0.0, 1.0, 0.0, 1.0])
    fr = MetricsFrame.from_metrics(ms)
    assert len(fr) == K
    np.testing.assert_allclose(fr.load_imbalance(), np.full(K, float(E)))
    s = fr.summary()
    assert s["routed_steps"] == 2 and s["gate_drop_rate"] == 0.5
    assert s["router_entropy"] == pytest.approx(0.7)
    # uniform load = perfect balance; zero load reports 0, not a NaN
    np.testing.assert_allclose(load_imbalance(np.ones(E)), 1.0)
    np.testing.assert_allclose(load_imbalance(np.zeros(E)), 0.0)


def test_router_health_over_history():
    hist = [{"loss": 1.0},                       # pre-frame record
            {"loss": 0.9, "router_entropy": 0.6, "load_imbalance": 2.0,
             "gate_dropped": 0.0},
            {"loss": 0.8, "router_entropy": 0.0, "load_imbalance": 0.0,
             "gate_dropped": 1.0}]
    rh = router_health(hist)
    assert rh["records"] == 2
    assert rh["gate_drop_rate"] == 0.5
    # routed records only: the dropped step's zeros don't dilute health
    assert rh["router_entropy"] == pytest.approx(0.6)
    assert router_health([{"loss": 1.0}])["records"] == 0


# ---------------------------------------------------------------------------
# registry: percentile math, NaN safety, export formats, live views
# ---------------------------------------------------------------------------

def test_registry_percentiles_match_numpy():
    reg = MetricsRegistry()
    h = reg.histogram("serve/ttft_s")
    xs = np.random.RandomState(0).lognormal(size=257)
    for x in xs:
        h.observe(x)
    ps = (50, 90, 99, 99.9, 7.5)
    got = h.percentiles(ps)
    for p in ps:
        assert got[p] == float(np.percentile(np.float64(xs), p))
    snap = h.snapshot()
    assert snap["count"] == 257
    assert snap["sum"] == pytest.approx(xs.sum())


def test_registry_empty_histogram_is_nan_safe():
    """The zero-request serve crash (ISSUE 10 satellite): percentiles on
    an empty histogram return NaN instead of raising."""
    h = MetricsRegistry().histogram("serve/ttft_s")
    pct = h.percentiles()
    assert set(pct) == {50, 90, 99}
    assert all(np.isnan(v) for v in pct.values())
    snap = h.snapshot()
    assert snap["count"] == 0 and np.isnan(snap["mean"])
    json.dumps(MetricsRegistry().to_json())     # and it still exports


def test_registry_export_formats(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve/requests", "total requests").inc(3)
    reg.gauge("serve/wall_s").set(1.5)
    h = reg.histogram("serve/ttft_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    s = reg.series("serve/tick_log")
    s.append(240.0, label="prefill")
    s.append(5.0, label="decode")
    s.append(5.0, label="decode")

    doc = json.loads(reg.to_json(str(tmp_path / "m.json")))
    assert doc["serve/requests"] == {"type": "counter", "value": 3.0}
    assert doc["serve/tick_log"]["by_label"]["decode"] == {
        "count": 2, "sum": 10.0}

    prom = reg.to_prometheus(str(tmp_path / "m.prom"))
    assert "# HELP serve_requests total requests" in prom
    assert "# TYPE serve_requests counter" in prom
    assert "serve_requests 3.0" in prom
    assert 'serve_ttft_s{quantile="0.5"} ' in prom
    assert "serve_ttft_s_count 3" in prom
    assert 'serve_tick_log_count{label="decode"} 2' in prom
    assert (tmp_path / "m.prom").read_text() == prom


def test_registry_series_views_are_live():
    """items/values are the live backing lists (the schedulers' legacy
    tick_log/alive_log attributes alias them, not copy them)."""
    s = MetricsRegistry().series("serve/tick_log")
    items, values = s.items, s.values
    s.append(7.0, label="decode")
    assert items == [("decode", 7.0)] and values == [7.0]


def test_registry_kind_collision_asserts():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(AssertionError):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# instrumentation coverage: trainer + scheduler under the hostsync guard
# ---------------------------------------------------------------------------

def test_trainer_instrumentation_coverage():
    """A tiny instrumented Trainer run emits the §15 span vocabulary
    (chunk dispatch/execute/fetch + prefetch produce/wait) and the
    MetricsFrame lands in the history records — with this module under
    the conftest transfer guard, the run also proves the tracer adds no
    hidden host syncs."""
    cfg = _cfg()
    tc = TrainConfig(lr=1e-3, warmup_steps=2, steps=4, seed=0)
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
    tracer = Tracer(enabled=True)
    trainer = Trainer(cfg, tc, lambda i: task.sample_batch(i, 4), chunk=2,
                      strategy="traced_cond", log=None, tracer=tracer)
    _, history = trainer.run()
    names = {e[1] for e in tracer.events}
    assert {"train_chunk", "chunk.execute", "chunk.fetch",
            "prefetch.produce", "prefetch.wait"} <= names
    assert history
    for rec in history:
        assert {"router_entropy", "load_imbalance",
                "gate_dropped"} <= set(rec)
    # the exported trace of a real run is loadable Chrome JSON
    json.dumps(tracer.export())


def test_scheduler_obs_and_compat_views():
    """An instrumented ContinuousScheduler run: tick spans recorded,
    TTFT/latency histograms populated at retire time, and the legacy
    tick_log/alive_log attributes are exact views over the registry
    series."""
    cfg = _cfg(moe=False)
    params = init_model(KEY, cfg)
    reqs = [Request(rid=i, tokens=np.asarray([3 + i, 4, 5], np.int32),
                    max_new=3, arrival=0.0) for i in range(3)]
    reg, tracer = MetricsRegistry(), Tracer(enabled=True)
    sched = ContinuousScheduler(params, cfg, GenerateConfig(max_new=3),
                                n_slots=2, prefill_buckets=(4,),
                                registry=reg, tracer=tracer)
    results = sched.run(reqs)
    assert len(results) == 3

    names = {e[1] for e in tracer.events}
    assert {"sched.admit", "sched.prefill", "sched.decode"} <= names
    assert reg.histogram("serve/ttft_s").count == 3
    assert reg.histogram("serve/per_token_latency_s").count == 3
    assert sched.tick_log is reg.series("serve/tick_log").items
    assert sched.alive_log is reg.series("serve/alive_log").values
    assert any(lab == "prefill" for lab, _ in sched.tick_log)
    assert any(lab == "decode" for lab, _ in sched.tick_log)
    labels = {lab for lab, _ in sched.tick_log}
    assert labels <= {"prefill", "decode"}
