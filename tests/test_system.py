"""End-to-end system behaviour: training converges, gating dropout
regularizes at matched semantics, serving works, dry-run machinery runs."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data import MTTaskConfig, MultilingualMT
from repro.models import init_model
from repro.training import init_train_state, make_eval_step, make_train_step


def _train(cfg, steps=60, batch=16, seed=0, gd_host=True):
    tc = TrainConfig(lr=2e-3, warmup_steps=20, steps=steps, seed=seed)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=4))
    params = init_model(jax.random.PRNGKey(seed), cfg)
    state = init_train_state(params, tc)
    step = make_train_step(cfg, tc)
    from repro.core.gating_dropout import drop_decision_host
    gd = cfg.moe.gating_dropout if cfg.moe is not None else None
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in task.sample_batch(i, batch).items()
             if k != "lang"}
        dec = drop_decision_host(gd, seed, i) if (gd and gd.enabled and gd_host) else None
        state, m = step(state, b, dec if dec is not None else False)
        losses.append(float(m["loss"]))
    return state, losses, task


def test_training_reduces_loss():
    cfg = reduced(get_config("zcode-m3-base"))
    _, losses, _ = _train(cfg, steps=50)
    assert losses[-1] < losses[0] * 0.85
    assert np.isfinite(losses).all()


def test_gate_drop_trains_and_drops():
    import dataclasses
    from repro.configs.base import GatingDropoutConfig
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(cfg.moe, gating_dropout=GatingDropoutConfig(
        mode="gate_drop", rate=0.4))
    cfg = dataclasses.replace(cfg, moe=moe)
    _, losses, _ = _train(cfg, steps=50)
    assert losses[-1] < losses[0] * 0.9


def test_hash_layer_baseline_trains():
    import dataclasses
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(cfg.moe, router_type="hash")
    cfg = dataclasses.replace(cfg, moe=moe)
    _, losses, _ = _train(cfg, steps=40)
    assert losses[-1] < losses[0]


def test_eval_step_runs():
    cfg = reduced(get_config("zcode-m3-base"))
    state, _, task = _train(cfg, steps=10)
    ev = make_eval_step(cfg)
    b = {k: jnp.asarray(v) for k, v in task.sample_batch(999, 8).items()
         if k != "lang"}
    m = ev(state["params"], b)
    assert np.isfinite(float(m["loss"]))


def test_serve_cli_runs():
    out = run_py("""
import sys
sys.argv = ['serve', '--arch', 'yi-6b', '--reduced', '--batch', '2',
            '--prompt-len', '16', '--max-new', '4']
from repro.launch.serve import main
main()
""", n_devices=1)
    assert "tok/s" in out          # engine-backed CLI reports throughput
    assert "ms/step" in out


def test_serve_cli_beam_runs():
    out = run_py("""
import sys
sys.argv = ['serve', '--arch', 'zcode-m3-base', '--reduced', '--batch', '2',
            '--prompt-len', '8', '--max-new', '4', '--beam', '2']
from repro.launch.serve import main
main()
""", n_devices=1)
    assert "beam=2" in out and "tok/s" in out


def test_dryrun_artifacts_have_roofline_inputs():
    """Artifacts written by the dry-run sweeps carry all roofline inputs."""
    art = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "artifacts", "dryrun")
    if not os.path.isdir(art) or not os.listdir(art):
        pytest.skip("dry-run artifacts not generated yet")
    files = [f for f in os.listdir(art) if f.endswith(".json")]
    assert files
    for f in files[:10]:
        with open(os.path.join(art, f)) as fh:
            d = json.load(fh)
        assert d["flops"] > 0
        assert "memory" in d and "collectives" in d
        assert d["n_params"] > 0


def test_moe_train_matches_between_strategies():
    """host_cond (static False) and traced_cond (in-graph draw that lands
    False) produce identical losses on non-dropped steps."""
    import dataclasses
    from repro.configs.base import GatingDropoutConfig
    from repro.core.gating_dropout import drop_decision_host
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(cfg.moe, jitter_eps=0.0,
                              gating_dropout=GatingDropoutConfig(
                                  mode="gate_drop", rate=0.3))
    cfg = dataclasses.replace(cfg, moe=moe)
    tc = TrainConfig(lr=1e-3, warmup_steps=10, seed=3)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=4))
    params = init_model(jax.random.PRNGKey(3), cfg)
    b = {k: jnp.asarray(v) for k, v in task.sample_batch(0, 8).items()
         if k != "lang"}
    step = make_train_step(cfg, tc, jit=False)
    s1 = init_train_state(params, tc)
    s2 = init_train_state(params, tc)
    dec0 = drop_decision_host(moe.gating_dropout, 3, 0)
    _, m_host = step(s1, b, dec0)
    _, m_traced = step(s2, b, None)   # in-graph draw for step 0, same seed
    np.testing.assert_allclose(float(m_host["loss"]),
                               float(m_traced["loss"]), rtol=1e-5)
