"""Scan-fused Trainer (DESIGN.md §8): a K-step fused chunk must be
BITWISE-identical to K legacy per-step calls (params + opt state, both
strategies, gating dropout on); vectorized batch synthesis must equal the
loop reference; the prefetcher must preserve order and surface errors;
the host_cond dropped run executable must contain zero all-to-alls."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.configs.base import (EncDecConfig, GatingDropoutConfig,
                                ModelConfig, MoEConfig, TrainConfig)
from repro.core.gating_dropout import drop_decision_host
from repro.data import (LMTaskConfig, MTTaskConfig, MultilingualMT,
                        Prefetcher, SyntheticLM, stack_batches)
from repro.models import init_model
from repro.training import (Trainer, init_train_state, make_chunk_step,
                            make_train_step, same_decision_runs)

KEY = jax.random.PRNGKey(0)


def _cfg(rate=0.5, mode="gate_drop"):
    return ModelConfig(d_model=32, d_ff=64, vocab=64, n_layers=2, n_heads=2,
                       n_kv_heads=2, remat=False, dtype="float32",
                       param_dtype="float32",
                       moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64,
                                     jitter_eps=0.0,
                                     gating_dropout=GatingDropoutConfig(
                                         mode=mode, rate=rate)))


def _task_and_batch_fn(cfg, batch=4, seq=16):
    task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=seq))
    return task, lambda i: task.sample_batch(i, batch)


def _legacy(cfg, tc, batch_fn, steps, strategy):
    """The seed-era loop: one jitted dispatch per step; host_cond draws the
    bit on the host (static), traced_cond computes it in-graph (None)."""
    gd = cfg.moe.gating_dropout
    step = make_train_step(cfg, tc)
    s = init_train_state(init_model(jax.random.PRNGKey(tc.seed), cfg), tc)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in batch_fn(i).items()}
        dec = (drop_decision_host(gd, tc.seed, i)
               if strategy == "host_cond" else None)
        s, _ = step(s, b, dec)
    return s


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fused chunk == legacy per-step, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["traced_cond", "host_cond"])
def test_fused_chunk_bitwise_equals_per_step(strategy):
    """One 4-step scan-fused chunk == 4 legacy per-step calls, bit for bit
    (params AND opt state), with gating dropout drawing a nontrivial
    decision pattern at rate 0.5."""
    cfg = _cfg()
    tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3)
    _, batch_fn = _task_and_batch_fn(cfg)
    K = 4
    gd = cfg.moe.gating_dropout
    decs = [drop_decision_host(gd, tc.seed, i) for i in range(K)]
    assert len(set(decs)) == 2, f"want both decisions in {decs}"

    chunk = make_chunk_step(cfg, tc)
    s = init_train_state(init_model(jax.random.PRNGKey(tc.seed), cfg), tc)
    if strategy == "traced_cond":
        batches = {k: jnp.asarray(v)
                   for k, v in stack_batches(batch_fn, 0, K).items()}
        s, ms = chunk(s, batches, None)
        assert ms["loss"].shape == (K,)       # on-device per-step metrics
    else:
        for lo, hi, dec in same_decision_runs(gd, tc.seed, 0, K):
            sub = {k: jnp.asarray(v)
                   for k, v in stack_batches(batch_fn, lo, hi).items()}
            s, ms = chunk(s, sub, dec)
            assert ms["loss"].shape == (hi - lo,)
    _assert_bitwise(s, _legacy(cfg, tc, batch_fn, K, strategy))


@pytest.mark.parametrize("strategy", ["traced_cond", "host_cond"])
def test_trainer_end_to_end_bitwise(strategy):
    """Trainer.run() (schedule + prefetch thread + run splitting + metric
    fetch at boundaries) over 7 steps with an uneven chunk size == the
    legacy loop, bit for bit."""
    cfg = _cfg()
    tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=3, steps=7)
    _, batch_fn = _task_and_batch_fn(cfg)
    tr = Trainer(cfg, tc, batch_fn, chunk=3, strategy=strategy, log=None)
    state, history = tr.run()
    _assert_bitwise(state, _legacy(cfg, tc, batch_fn, tc.steps, strategy))
    assert history and history[-1]["step"] == tc.steps - 1
    for rec in history:
        for k in ("loss", "acc", "lr", "tok_s", "time_s"):
            assert np.isfinite(rec[k]), (rec, k)


def test_trainer_counts_encoder_tokens():
    """tok/s accounting: MT batches consume enc_tokens + tokens; LM only
    tokens (the seed launcher counted decoder tokens only — ~2x under
    on the paper's main task)."""
    cfg = dataclasses.replace(_cfg(), family="encdec",
                              encdec=EncDecConfig(n_encoder_layers=1,
                                                  encoder_seq=8))
    tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=0, steps=2)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=4, max_len=8))
    fn = lambda i: {k: v for k, v in task.sample_batch(i, 2).items()  # noqa: E731
                    if k != "lang"}
    tr = Trainer(cfg, tc, fn, chunk=2, log=None, log_every=1)
    _, hist = tr.run()
    b = fn(0)
    per_step = b["tokens"].size + b["enc_tokens"].size
    assert per_step == 2 * b["tokens"].size
    # tok_s * time_s at the final record == all tokens consumed
    approx = hist[-1]["tok_s"] * hist[-1]["time_s"]
    np.testing.assert_allclose(approx, tc.steps * per_step, rtol=1e-3)


def test_schedule_aligns_eval_steps_to_chunk_ends():
    cfg = _cfg()
    tc = TrainConfig(steps=10, seed=0)
    _, batch_fn = _task_and_batch_fn(cfg)
    tr = Trainer(cfg, tc, batch_fn, chunk=4, eval_every=3,
                 eval_fn=lambda s, i: {}, log=None)
    spans = tr.schedule()
    assert spans[0] == (0, 1)                       # eval at step 0
    assert [e for _, e in spans] == sorted({e for _, e in spans})
    assert all(e - s <= 4 for s, e in spans)
    # every eval step i is the LAST step of its chunk (end == i + 1)
    ends = {e for _, e in spans}
    for i in (0, 3, 6, 9):
        assert i + 1 in ends, (i, spans)
    # contiguous cover of [0, steps)
    assert spans[0][0] == 0 and spans[-1][1] == tc.steps
    assert all(spans[i][1] == spans[i + 1][0] for i in range(len(spans) - 1))


def test_same_decision_runs_cover_and_are_maximal():
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.5)
    runs = same_decision_runs(gd, 3, 0, 32)
    assert runs[0][0] == 0 and runs[-1][1] == 32
    assert all(runs[i][1] == runs[i + 1][0] for i in range(len(runs) - 1))
    assert all(runs[i][2] != runs[i + 1][2] for i in range(len(runs) - 1))
    for lo, hi, dec in runs:
        assert all(drop_decision_host(gd, 3, i) == dec for i in range(lo, hi))
    assert same_decision_runs(None, 0, 5, 9) == [(5, 9, False)]


def test_dropped_chunk_executable_has_no_alltoall():
    """The tentpole's structural claim survives fusion: the host_cond
    dropped RUN executable (scan over K steps, decision baked False->True
    static) contains zero all-to-all ops; the routed one contains them."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (GatingDropoutConfig, ModelConfig, MoEConfig,
                                TrainConfig)
from repro.core.moe import ParallelContext
from repro.data import LMTaskConfig, SyntheticLM, stack_batches
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.training import init_train_state, make_chunk_step
mesh = make_mesh((4, 2), ('data', 'model'))
ctx = ParallelContext(mesh=mesh)
cfg = ModelConfig(d_model=64, d_ff=128, vocab=100, n_layers=1, n_heads=2,
                  n_kv_heads=2, remat=False, dtype='float32',
                  param_dtype='float32',
                  moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                                backend='sharded',
                                gating_dropout=GatingDropoutConfig(
                                    mode='gate_drop', rate=0.3,
                                    strategy='host_cond')))
tc = TrainConfig(lr=1e-3, warmup_steps=2, seed=0)
task = SyntheticLM(LMTaskConfig(vocab=cfg.vocab, seq_len=16))
batches = {k: jnp.asarray(v) for k, v in
           stack_batches(lambda i: task.sample_batch(i, 8), 0, 3).items()}
state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
chunk = make_chunk_step(cfg, tc, ctx, jit=False)
for dec, name in [(False, 'routed'), (True, 'dropped')]:
    txt = jax.jit(chunk, static_argnums=(2,)).lower(
        state, batches, dec).compile().as_text()
    print(name, txt.count('all-to-all'))
""")
    lines = dict(l.split() for l in out.strip().splitlines())
    assert int(lines["routed"]) > 0
    assert int(lines["dropped"]) == 0


# ---------------------------------------------------------------------------
# vectorized batch synthesis == loop reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dae", [0.0, 0.3])
@pytest.mark.parametrize("lang", [None, 2])
def test_mt_vectorized_equals_loop(dae, lang):
    task = MultilingualMT(MTTaskConfig(vocab=512, n_langs=8, max_len=32,
                                       dae_frac=dae))
    for step in (0, 7, 123):
        a = task.sample_batch(step, 16, lang=lang)
        b = task.sample_batch_loop(step, 16, lang=lang)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{step}/{k}")


def test_mt_vectorized_equals_loop_truncation_and_shards():
    """max_len < src_len+2 exercises row truncation; shards must stay
    disjoint and loop-equal."""
    task = MultilingualMT(MTTaskConfig(vocab=512, n_langs=4, max_len=16,
                                       src_len=(8, 24), dae_frac=0.2))
    for step in range(4):
        for shard in (0, 1):
            a = task.sample_batch(step, 8, shard=shard, n_shards=2)
            b = task.sample_batch_loop(step, 8, shard=shard, n_shards=2)
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
    s0 = task.sample_batch(0, 8, shard=0, n_shards=2)
    s1 = task.sample_batch(0, 8, shard=1, n_shards=2)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_lm_vectorized_equals_loop():
    for kw in (dict(vocab=512, seq_len=128), dict(vocab=64, seq_len=16,
                                                  seed=5)):
        task = SyntheticLM(LMTaskConfig(**kw))
        for step in (0, 5, 99):
            a = task.sample_batch(step, 8)
            b = task.sample_batch_loop(step, 8)
            for k in a:
                np.testing.assert_array_equal(a[k], b[k], err_msg=f"{kw}")


def test_mt_batch_shapes_and_special_tokens():
    """Invariants the model relies on: BOS at dec[0], one EOS per label
    row, mask covers exactly the target + EOS."""
    task = MultilingualMT(MTTaskConfig(vocab=512, n_langs=8, max_len=32))
    b = task.sample_batch(0, 16)
    assert b["tokens"].shape == (16, 32)
    assert (b["tokens"][:, 0] == 1).all()           # BOS
    assert ((b["labels"] == 2).sum(1) == 1).all()   # exactly one EOS
    eos_pos = (b["labels"] == 2).argmax(1)
    np.testing.assert_array_equal(b["loss_mask"].sum(1), eos_pos + 1)


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------

def test_prefetcher_preserves_order():
    out = list(Prefetcher(lambda x: x * x, range(20), depth=2))
    assert out == [x * x for x in range(20)]


def test_prefetcher_propagates_errors():
    def boom(x):
        if x == 3:
            raise ValueError("synthetic failure")
        return x

    it = Prefetcher(boom, range(10), depth=2)
    got = []
    with pytest.raises(ValueError, match="synthetic failure"):
        for v in it:
            got.append(v)
    assert got == [0, 1, 2]


def test_prefetcher_close_unblocks_worker():
    p = Prefetcher(lambda x: x, range(1000), depth=1)
    assert next(p) == 0
    p.close()
    p._thread.join(timeout=5)
    assert not p._thread.is_alive()


def test_stack_batches_leading_axis():
    task = SyntheticLM(LMTaskConfig(vocab=64, seq_len=8))
    st = stack_batches(lambda i: task.sample_batch(i, 4), 3, 7)
    assert st["tokens"].shape == (4, 4, 8)
    for j, i in enumerate(range(3, 7)):
        np.testing.assert_array_equal(st["tokens"][j],
                                      task.sample_batch(i, 4)["tokens"])
