"""corpus_bleu pinned against hand-computed values, incl. the sacreBLEU
brevity-penalty boundary (BP == 1 when hyp_len == ref_len)."""
import math

import pytest

from repro.metrics import corpus_bleu, strip_special, token_accuracy

EPS = 1e-9


def _smoothed(match_totals):
    lp = sum(math.log((m + EPS) / (t + EPS)) for m, t in match_totals)
    return math.exp(lp / len(match_totals))


def test_perfect_match_is_100():
    assert corpus_bleu([[5, 6, 7, 8, 9]], [[5, 6, 7, 8, 9]]) == \
        pytest.approx(100.0, abs=1e-3)


def test_hand_computed_example():
    """hyp [5,6,7,9] vs ref [5,6,7,8]: 1-gram 3/4, 2-gram 2/3, 3-gram 1/2,
    4-gram 0/1 (eps-smoothed); hyp_len == ref_len so BP == 1 exactly."""
    expected = 100.0 * _smoothed([(3, 4), (2, 3), (1, 2), (0, 1)])
    assert corpus_bleu([[5, 6, 7, 9]], [[5, 6, 7, 8]]) == \
        pytest.approx(expected, rel=1e-6)


def test_brevity_penalty_strictly_short():
    """Perfect 4-token prefix of a 6-token ref: every n-gram precision is
    1, so the score is exactly the brevity penalty exp(1 - 6/4)."""
    short = corpus_bleu([[5, 6, 7, 8]], [[5, 6, 7, 8, 9, 10]])
    assert short == pytest.approx(100.0 * math.exp(1 - 6 / 4), rel=1e-4)


def test_brevity_penalty_equal_length_boundary():
    """hyp_len == ref_len must NOT be penalized (sacreBLEU: BP applies
    only when hyp_len < ref_len; the old code used a strict > and
    penalized exact-length hypotheses).

    hyp [5,6,7,8,9,9] vs ref [5,6,7,8,9,10]: by hand 1g 5/6, 2g 4/5,
    3g 3/4, 4g 2/3 and BP must be exactly 1."""
    expected = 100.0 * _smoothed([(5, 6), (4, 5), (3, 4), (2, 3)])
    got = corpus_bleu([[5, 6, 7, 8, 9, 9]], [[5, 6, 7, 8, 9, 10]])
    assert got == pytest.approx(expected, rel=1e-6)


def test_longer_hypothesis_not_brevity_penalized():
    """hyp_len > ref_len: precision drops but no BP applies. 6 tokens vs
    4-token ref, perfect prefix: 1g 4/6, 2g 3/5, 3g 2/4, 4g 1/3, BP 1."""
    expected = 100.0 * _smoothed([(4, 6), (3, 5), (2, 4), (1, 3)])
    got = corpus_bleu([[5, 6, 7, 8, 9, 10]], [[5, 6, 7, 8]])
    assert got == pytest.approx(expected, rel=1e-6)


def test_empty_hypothesis_is_zero():
    assert corpus_bleu([[]], [[5, 6, 7]]) == 0.0


def test_corpus_level_aggregation():
    """Corpus BLEU pools n-gram counts and lengths over the whole corpus
    (it is NOT a mean of sentence scores): two half-matching sentences
    == pooled counts."""
    hyps = [[5, 6, 7, 8], [9, 10, 11, 12]]
    refs = [[5, 6, 7, 8], [9, 10, 13, 14]]
    # pooled: 1g (4+2)/8, 2g (3+1)/6, 3g (2+0)/4, 4g (1+0)/2; lens 8 == 8
    expected = 100.0 * _smoothed([(6, 8), (4, 6), (2, 4), (1, 2)])
    assert corpus_bleu(hyps, refs) == pytest.approx(expected, rel=1e-6)


def test_strip_special_and_accuracy():
    assert strip_special([7, 8, 0, 9, 2, 11]) == [7, 8, 9]
    import numpy as np
    pred = np.array([[1, 2, 3]])
    lab = np.array([[1, 2, 9]])
    mask = np.ones((1, 3), np.float32)
    assert token_accuracy(pred, lab, mask) == pytest.approx(2 / 3)
