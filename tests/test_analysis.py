"""The compiled-program lint subsystem (src/repro/analysis, DESIGN.md §12).

Three layers:
  * IR walkers as pure functions — canned-HLO parsing, dtype table,
    jaxpr dtype-flow / pallas-launch extraction;
  * each pass catches a DELIBERATELY seeded violation (an extra
    all_to_all, an f32 upcast, an oversized block footprint, an extra
    pallas launch, a hidden host pull, a jit cache miss) — a lint suite
    that never fires is indistinguishable from one that never looks;
  * the registry/driver surface: suppressions, gating, the CLI.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, SRC, run_py
from repro.analysis import (DTYPE_BYTES, UnknownDtypeError,
                            collectives_summary, parse_collectives,
                            parse_hlo, shape_bytes)
from repro.analysis.executables import Artifacts, ExecutableSpec
from repro.analysis.hostsync import guard_host_transfers, jit_cache_sizes
from repro.analysis.jaxprs import (count_primitive, f32_upcast_dots,
                                   pallas_launches, walk_eqns)
from repro.analysis.lint import format_report, gate
from repro.analysis.passes import available_passes, get_pass, run_pass

pytestmark = pytest.mark.lint


# --------------------------------------------------------------- dtype table

def test_shape_bytes_quantized_wire_dtypes():
    """The seed parser priced every unknown dtype at 4 bytes — the 8-bit
    wire dtypes the compressed substrate moves were 4x over-priced."""
    assert shape_bytes("s8", (8, 16)) == 128
    assert shape_bytes("u8", (8, 16)) == 128
    assert shape_bytes("f8e4m3fn", (4, 4)) == 16
    assert shape_bytes("f8e5m2", (4,)) == 4
    assert shape_bytes("pred", (32,)) == 32
    assert shape_bytes("bf16", (2, 3)) == 12
    assert shape_bytes("f32", "8,16") == 512      # XLA's comma string
    assert shape_bytes("f32", ()) == 4            # scalar
    assert DTYPE_BYTES["c128"] == 16


def test_shape_bytes_unknown_dtype_raises():
    with pytest.raises(UnknownDtypeError):
        shape_bytes("f128", (2,))
    with pytest.raises(KeyError):                 # it IS a KeyError
        shape_bytes("mystery", (2,))


# --------------------------------------------------------------- HLO walker

_CANNED = """\
HloModule jit_step, entry_computation_layout={(f32[8,16]{1,0})->f32[8,16]{1,0}}

%fused_computation (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  ROOT %m = f32[8,16]{1,0} multiply(%p0, %p0)
}

ENTRY %main.42 (arg0: f32[8,16]) -> f32[8,16] {
  %arg0 = f32[8,16]{1,0} parameter(0)
  %all-to-all.1 = (f32[8,16]{1,0}, u8[64]{0}) all-to-all(%arg0, %arg0), \
replica_groups={{0,1,2,3},{4,5,6,7}}, channel_id=3, dimensions={0}
  %get-tuple-element.5 = f32[8,16]{1,0} get-tuple-element(%all-to-all.1), index=0
  %ag-start = f32[16,16]{1,0} all-gather-start(%get-tuple-element.5), \
replica_groups=[2,4], dimensions={0}, channel_id=4
  %ag-done = f32[16,16]{1,0} all-gather-done(%ag-start)
  %fus = f32[8,16]{1,0} fusion(%get-tuple-element.5), kind=kLoop, \
calls=%fused_computation
  ROOT %ar = f32[8,16]{1,0} all-reduce(%fus), replica_groups={{0,1,2,3,4,5,6,7}}, \
to_apply=%add
}
"""


def test_parse_hlo_structure():
    mod = parse_hlo(_CANNED)
    assert mod.entry == "main.42"
    assert set(mod.computations) == {"fused_computation", "main.42"}
    a2a = mod.find("all-to-all")
    assert len(a2a) == 1
    i = a2a[0]
    # tuple result flattened; layout braces skipped
    assert [(s.dtype, s.dims) for s in i.shapes] == \
        [("f32", (8, 16)), ("u8", (64,))]
    assert i.result_bytes == 8 * 16 * 4 + 64
    assert i.replica_groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert i.group_size == 4
    assert i.channel_id == 3
    assert i.computation == "main.42"
    # fusion body resolution
    fus = mod.find("fusion")[0]
    assert [c.name for c in mod.called_by(fus)] == ["fused_computation"]
    # root flag
    assert mod.find("all-reduce")[0].is_root


def test_parser_excludes_gte_and_counts_async_once():
    """The two structural traps: a get-tuple-element line that textually
    CONTAINS "all-to-all" (its operand name) must not count, and an
    async -start/-done pair is one collective, not two."""
    mod = parse_hlo(_CANNED)
    summary = collectives_summary(mod)
    assert summary["all-to-all"]["count"] == 1
    assert summary["all-gather"]["count"] == 1          # start+done = 1
    gte = [i for i in mod.instructions()
           if i.opcode == "get-tuple-element"]
    assert len(gte) == 1 and "all-to-all" in gte[0].raw


def test_collectives_summary_wire_model():
    s = collectives_summary(parse_hlo(_CANNED))
    a2a_payload = 8 * 16 * 4 + 64
    assert s["all-to-all"]["bytes"] == a2a_payload
    assert s["all-to-all"]["wire_bytes"] == a2a_payload * 3 / 4
    assert s["all-to-all"]["max_group"] == 4
    # iota groups [2,4] -> two groups of 4
    ag = 16 * 16 * 4
    assert s["all-gather"]["bytes"] == ag
    assert s["all-gather"]["wire_bytes"] == ag * 3 / 4
    ar = 8 * 16 * 4
    assert s["all-reduce"]["wire_bytes"] == 2 * ar * 7 / 8
    # the back-compat wrapper is the same numbers
    assert parse_collectives(_CANNED) == s


# -------------------------------------------------------------- jaxpr walker

def test_walk_eqns_recurses_with_path():
    def f(x):
        return jax.lax.scan(lambda c, t: (c + jnp.sin(t), c), x, x)[0]

    jx = jax.make_jaxpr(f)(jnp.ones(4))
    assert count_primitive(jx, "sin") == 1       # scan body counted ONCE
    paths = [p for eqn, p in walk_eqns(jx) if eqn.primitive.name == "sin"]
    assert paths == [("scan",)]


def test_f32_upcast_dots_catches_seeded_upcast():
    x = jnp.ones((128, 128), jnp.bfloat16)

    def bad(a, b):
        return a.astype(jnp.float32) @ b.astype(jnp.float32)

    hits = f32_upcast_dots(jax.make_jaxpr(bad)(x, x))
    assert len(hits) == 1
    assert hits[0].out_elems == 128 * 128
    assert set(hits[0].src_dtypes) == {"bfloat16"}


def test_f32_upcast_dots_whitelists():
    x = jnp.ones((128, 128), jnp.bfloat16)
    # a dot that KEEPS bf16 operands never matches, whatever it accumulates
    ok = jax.make_jaxpr(
        lambda a, b: jax.lax.dot(a, b,
                                 preferred_element_type=jnp.float32))(x, x)
    assert f32_upcast_dots(ok) == []
    # small outputs (router logits shape) stay below min_elems
    s = jnp.ones((32, 8), jnp.bfloat16)
    small = jax.make_jaxpr(
        lambda a: a.astype(jnp.float32) @ a.astype(jnp.float32).T)(s)
    assert f32_upcast_dots(small) == []
    assert len(f32_upcast_dots(small, min_elems=512)) == 1
    # native f32 dots are not upcasts
    f = jnp.ones((128, 128), jnp.float32)
    assert f32_upcast_dots(jax.make_jaxpr(lambda a: a @ a)(f)) == []


def _flash_fn():
    from repro.kernels.flash_decode import flash_decode
    B, H, KV, hd, S = 4, 2, 1, 16, 32
    q = jnp.ones((B, H, hd))
    k = jnp.ones((B, S, KV, hd))
    v = jnp.ones((B, S, KV, hd))
    idx = jnp.full((B,), 7, jnp.int32)
    return (lambda *a: flash_decode(*a, interpret=True)), (q, k, v, idx)


def test_pallas_launches_extracts_real_grid_mapping():
    fn, args = _flash_fn()
    launches = pallas_launches(jax.make_jaxpr(fn)(*args))
    assert len(launches) == 1
    l = launches[0]
    assert l.grid and all(g >= 1 for g in l.grid)
    assert l.buffers and all(b.bytes > 0 for b in l.buffers)
    assert l.vmem_bytes() >= sum(b.bytes for b in l.buffers)


# ------------------------------------------------- passes catch seeded bugs

def _spec(name, fn, args, expect, **kw):
    return ExecutableSpec(name=name, build=lambda: (fn, args),
                          expect=expect, **kw)


def test_dtype_flow_pass_fires_on_upcast():
    x = jnp.ones((128, 128), jnp.bfloat16)
    spec = _spec("inject/upcast",
                 lambda a: a.astype(jnp.float32) @ a.astype(jnp.float32),
                 (x,), {"dtype-flow": {"min_elems": 4096}})
    fs = run_pass("dtype-flow", spec, Artifacts(spec))
    assert [f.severity for f in fs] == ["error"]
    assert "bfloat16" in fs[0].message and "jaxpr:" in fs[0].location
    ok, verdict = gate(fs)
    assert not ok and "FAIL" in verdict


def test_vmem_budget_pass_fires_on_oversized_blocks():
    """Seed an over-budget launch by shrinking the budget under the real
    footprint — equivalent to a block spec outgrowing VMEM."""
    fn, args = _flash_fn()
    real = pallas_launches(jax.make_jaxpr(fn)(*args))[0].vmem_bytes()
    spec = _spec("inject/vmem", fn, args,
                 {"vmem-budget": {"budget_bytes": real - 1}})
    fs = run_pass("vmem-budget", spec, Artifacts(spec))
    assert len(fs) == 1 and fs[0].severity == "error"
    assert "MiB" in fs[0].message and fs[0].location.startswith("pallas:")
    # at the real footprint it passes
    spec_ok = _spec("inject/vmem-ok", fn, args,
                    {"vmem-budget": {"budget_bytes": real}})
    assert run_pass("vmem-budget", spec_ok, Artifacts(spec_ok)) == []


def test_launch_count_pass_fires_on_extra_launch():
    fn, args = _flash_fn()

    def twice(*a):
        return fn(*a) + fn(*a)                  # a second pallas_call

    spec = _spec("inject/launches", twice, args,
                 {"launch-count": {"max": 1}})
    fs = run_pass("launch-count", spec, Artifacts(spec))
    assert len(fs) == 1 and "2 pallas_call" in fs[0].message


def test_host_sync_pass_fires_on_hidden_pull_and_cache_miss():
    def scenario():
        jit_f = jax.jit(lambda v: v * 2)
        jit_f(jnp.ones(4))                      # warmup
        with guard_host_transfers() as events:
            before = jit_cache_sizes([jit_f])
            float(jnp.sum(jit_f(jnp.ones(4))))  # hidden pull
            jit_f(jnp.ones(8))                  # shape leak -> retrace
            after = jit_cache_sizes([jit_f])
        return {"events": events,
                "cache_sizes": [("jit_f", before[0], after[0])]}

    spec = ExecutableSpec(name="inject/hostsync", build=lambda: (None, ()),
                          expect={"host-sync": {}}, scenario=scenario)
    fs = run_pass("host-sync", spec, Artifacts(spec))
    kinds = {f.location.split(":")[0] for f in fs}
    assert any("test_analysis" in f.location for f in fs
               if "__float__" in f.message), fs
    assert any(f.location == "jit:jit_f" and "re-traced" in f.message
               for f in fs)
    assert "jit" in kinds


def test_no_collectives_pass_fires_on_extra_all_to_all():
    """Seed the §3 violation on a real 8-device mesh: a 'dropped'
    executable that still carries an all_to_all, and a routed one whose
    bytes disagree with the cost model."""
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.analysis.executables import ExecutableSpec, Artifacts
from repro.analysis.passes import run_pass
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ('data',))

def leaky(x):   # pretends to be a zero-comm LOCAL path, but isn't
    def shard(x):
        return jax.lax.all_to_all(x, 'data', split_axis=0, concat_axis=1,
                                  tiled=True)
    return shard_map(shard, mesh=mesh, in_specs=P('data'),
                     out_specs=P('data'))(x)

x = jnp.ones((64, 64), jnp.float32)     # per-device shard (8, 64)
spec = ExecutableSpec(name='inject/leak', build=lambda: (leaky, (x,)),
                      expect={'no-collectives': {'zero': True}})
fs = run_pass('no-collectives', spec, Artifacts(spec))
assert len(fs) == 1 and fs[0].severity == 'error', fs
assert 'ZERO' in fs[0].message and 'all-to-all' in fs[0].location, fs

# count/bytes drift against the cost model is also an error
bytes_ = 64 * 64 * 4 // 8           # per-device result bytes
spec2 = ExecutableSpec(name='inject/drift', build=lambda: (leaky, (x,)),
                       expect={'no-collectives': {'cost': {
                           'calls': 2, 'bytes': bytes_ * 2,
                           'wire_bytes': 0.0}}})
fs2 = run_pass('no-collectives', spec2, Artifacts(spec2))
msgs = ' | '.join(f.message for f in fs2)
assert 'count 1 != cost model 2' in msgs, msgs
assert 'payload' in msgs and 'wire' in msgs, msgs

# exact agreement is clean
wire = bytes_ * (8 - 1) / 8
spec3 = ExecutableSpec(name='inject/exact', build=lambda: (leaky, (x,)),
                       expect={'no-collectives': {'cost': {
                           'calls': 1, 'bytes': bytes_,
                           'wire_bytes': wire}}})
assert run_pass('no-collectives', spec3, Artifacts(spec3)) == []

# and an executable EXPECTED to route but compiling to silence is flagged
spec4 = ExecutableSpec(name='inject/silent',
                       build=lambda: ((lambda y: y * 2), (x,)),
                       expect={'no-collectives': {'nonzero': True}})
fs4 = run_pass('no-collectives', spec4, Artifacts(spec4))
assert len(fs4) == 1 and 'silently elided' in fs4[0].message, fs4
print('OK')
""")
    assert "OK" in out


# ------------------------------------------------------ suppression + gate

def test_suppression_keeps_finding_but_passes_gate():
    x = jnp.ones((128, 128), jnp.bfloat16)
    spec = _spec("inject/suppressed",
                 lambda a: a.astype(jnp.float32) @ a.astype(jnp.float32),
                 (x,), {"dtype-flow": {}}, ignore=("dtype-flow",))
    fs = run_pass("dtype-flow", spec, Artifacts(spec))
    assert len(fs) == 1 and fs[0].suppressed
    ok, verdict = gate(fs)
    assert ok and "1 suppressed" in verdict
    assert "(suppressed)" in format_report(fs)
    assert fs[0].as_dict()["suppressed"] is True


def test_register_executable_parses_ignore_comment():
    from repro.analysis.executables import (_REGISTRY, get_executable,
                                            register_executable)
    try:
        register_executable(ExecutableSpec(
            name="inject/commented", build=lambda: (None, ()),
            expect={}))  # lint: ignore[vmem-budget, dtype-flow]
        spec = get_executable("inject/commented")
        assert spec.ignore == ("vmem-budget", "dtype-flow")
    finally:
        _REGISTRY.pop("inject/commented", None)


def test_pass_registry_surface():
    assert set(available_passes()) == {
        "no-collectives", "dtype-flow", "vmem-budget", "launch-count",
        "host-sync"}
    assert "scenario" in get_pass("host-sync").needs
    assert get_pass("no-collectives").needs == ("hlo",)
    with pytest.raises(KeyError, match="unknown lint pass"):
        get_pass("nope")


# -------------------------------------------------------------------- CLI

def test_lint_cli_gate_and_json(tmp_path):
    """The CI entry: `python -m repro.launch.lint --gate` on the 8-device
    CPU mesh, restricted to a cheap executable to keep the test fast."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)          # the CLI must set the mesh itself
    out_json = tmp_path / "lint.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--gate",
         "--only", "pallas_fused/fwd", "--only", "flash_decode/step",
         "--json-out", str(out_json)],
        capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "LINT GATE: ok" in r.stdout
    rep = json.loads(out_json.read_text())
    assert rep["ok"] is True and rep["findings"] == []


def test_lint_cli_list():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "repro.launch.lint", "--list"],
                       capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    for needle in ("no-collectives", "moe_layer/dense", "train_chunk/dropped",
                   "scheduler/ticks"):
        assert needle in r.stdout
