"""Launcher smoke coverage: `python -m repro.launch.train` end to end in a
subprocess (the exact user entrypoint — argparse, Trainer wiring, BLEU
eval, --json-out), asserting the JSON history is well-formed."""
import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_module(args, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"launcher failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_train_cli_smoke_json_history(tmp_path):
    """8 reduced steps with periodic BLEU eval; --batch/--seq shrunk so the
    chunk executables compile quickly. Asserts the --json-out schema the
    benchmarks consume."""
    out_json = str(tmp_path / "hist.json")
    # traced_cond -> one executable per chunk LENGTH (host_cond would also
    # specialize on the decision, doubling compile work — covered by
    # tests/test_trainer.py at tiny scale instead)
    stdout = run_module(["--reduced", "--steps", "8", "--eval-every", "4",
                         "--json-out", out_json,
                         "--batch", "4", "--seq", "16", "--chunk", "4",
                         "--strategy", "traced_cond",
                         "--microbatches", "2", "--schedule", "cosine"])
    with open(out_json) as f:
        data = json.load(f)
    assert data["arch"]
    assert data["gd"] is not None          # zcode-m3 carries a gd config
    hist = data["history"]
    assert hist, stdout
    steps = [r["step"] for r in hist]
    assert steps == sorted(steps)
    assert steps[-1] == 7
    for rec in hist:
        for k in ("loss", "acc", "lr", "tok_s", "time_s"):
            assert k in rec and np.isfinite(rec[k]), (rec, k)
        assert rec["tok_s"] > 0
    # --schedule cosine + warmup: lr must actually move between records
    lrs = {r["lr"] for r in hist}
    assert len(lrs) > 1, hist
    # eval steps (0, 4, last) carry a BLEU value
    bleu_steps = {r["step"] for r in hist if "bleu" in r}
    assert {0, 4, 7} <= bleu_steps, hist
    assert all(np.isfinite(r["bleu"]) for r in hist if "bleu" in r)
    # stdout mirrors the history as JSON lines
    assert any('"step": 7' in l for l in stdout.splitlines())
