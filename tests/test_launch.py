"""Launcher smoke coverage: `python -m repro.launch.{train,serve}` end to
end in a subprocess (the exact user entrypoints — argparse, Trainer /
scheduler wiring, --json-out), asserting the JSON outputs are
well-formed."""
import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_module(args, timeout=540, module="repro.launch.train"):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", module] + args,
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"launcher failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_train_cli_smoke_json_history(tmp_path):
    """8 reduced steps with periodic BLEU eval; --batch/--seq shrunk so the
    chunk executables compile quickly. Asserts the --json-out schema the
    benchmarks consume."""
    out_json = str(tmp_path / "hist.json")
    # traced_cond -> one executable per chunk LENGTH (host_cond would also
    # specialize on the decision, doubling compile work — covered by
    # tests/test_trainer.py at tiny scale instead)
    stdout = run_module(["--reduced", "--steps", "8", "--eval-every", "4",
                         "--json-out", out_json,
                         "--batch", "4", "--seq", "16", "--chunk", "4",
                         "--strategy", "traced_cond",
                         "--microbatches", "2", "--schedule", "cosine"])
    with open(out_json) as f:
        data = json.load(f)
    assert data["arch"]
    assert data["gd"] is not None          # zcode-m3 carries a gd config
    hist = data["history"]
    assert hist, stdout
    steps = [r["step"] for r in hist]
    assert steps == sorted(steps)
    assert steps[-1] == 7
    for rec in hist:
        for k in ("loss", "acc", "lr", "tok_s", "time_s"):
            assert k in rec and np.isfinite(rec[k]), (rec, k)
        assert rec["tok_s"] > 0
    # --schedule cosine + warmup: lr must actually move between records
    lrs = {r["lr"] for r in hist}
    assert len(lrs) > 1, hist
    # eval steps (0, 4, last) carry a BLEU value
    bleu_steps = {r["step"] for r in hist if "bleu" in r}
    assert {0, 4, 7} <= bleu_steps, hist
    assert all(np.isfinite(r["bleu"]) for r in hist if "bleu" in r)
    # stdout mirrors the history as JSON lines
    assert any('"step": 7' in l for l in stdout.splitlines())


def test_serve_cli_trace_smoke_json(tmp_path):
    """Continuous-batching serving loop end to end (DESIGN.md §9):
    synthetic Poisson trace through the scheduler, --json-out schema the
    benchmarks consume, every request admitted AND finished."""
    out_json = str(tmp_path / "serve.json")
    run_module(["--arch", "yi-6b", "--reduced", "--trace", "6",
                "--rate", "500", "--slots", "2", "--max-new", "6",
                "--buckets", "8", "--eos", "-1",
                "--json-out", out_json], module="repro.launch.serve")
    with open(out_json) as f:
        rec = json.load(f)
    assert rec["mode"] == "continuous"
    assert rec["n_requests"] == 6
    assert rec["scheduler"]["admitted"] == 6
    assert rec["scheduler"]["finished"] == 6
    assert rec["scheduler"]["max_concurrent"] <= 2
    # eos disabled: every request runs to its sampled budget in [2, 6]
    assert 6 * 2 <= rec["n_tokens"] <= 6 * 6
    assert rec["tok_s"] > 0
    for p in ("50", "90", "99"):
        assert np.isfinite(rec["ttft_s"][p])
        assert np.isfinite(rec["per_token_latency_s"][p])
    # mid-flight admission: 6 requests through 2 slots -> slots reused
    assert rec["scheduler"]["slot_reuse"] >= 4


def test_serve_cli_trace_comm_accounting(tmp_path):
    """MoE arch + --comm: the trace record prices every executed tick
    with the substrate bytes model (DESIGN.md §10) at --comm-ep."""
    out_json = str(tmp_path / "serve_comm.json")
    stdout = run_module(["--arch", "dbrx-132b", "--reduced", "--trace", "4",
                         "--rate", "500", "--slots", "2", "--max-new", "4",
                         "--buckets", "8", "--eos", "-1",
                         "--comm", "compressed", "--comm-ep", "8",
                         "--json-out", out_json],
                        module="repro.launch.serve")
    with open(out_json) as f:
        rec = json.load(f)
    comm = rec["comm"]
    assert comm["substrate"] == "compressed"
    assert comm["ep_model"] == 8
    assert comm["wire_bytes_total"] > 0
    assert comm["n_ticks"] == (rec["scheduler"]["prefill_calls"]
                               + rec["scheduler"]["decode_steps"])
    for p in ("50", "90", "99"):
        assert np.isfinite(comm["wire_bytes_per_tick"][p])
    assert "comm[compressed@ep=8]" in stdout


def test_dryrun_comm_table_cli():
    """--comm-table prints the per-substrate predicted bytes table with
    no lowering/compiling — must return in seconds."""
    stdout = run_module(["--comm-table", "--arch", "zcode-m3-base",
                         "--shape", "train_4k"],
                        module="repro.launch.dryrun", timeout=180)
    for name in ("dense", "hierarchical", "compressed",
                 "hierarchical_compressed", "vs dense"):
        assert name in stdout, stdout
