"""Partition-spec rules: validity on the production mesh (AbstractMesh —
no devices needed) + a real 8-device end-to-end sharded train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_py
from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import TrainConfig
from repro.core.moe import ParallelContext
from repro.launch.mesh import abstract_mesh
from repro.models.model import init_cache, init_model
from repro.parallel.sharding import cache_specs, param_specs, state_specs
from repro.training.steps import init_train_state


def _abstract_mesh(multi_pod=False):
    if multi_pod:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible_on_production_mesh(arch, multi_pod):
    """Every sharded dim must be divisible by its mesh-axis size — for the
    FULL configs on both production meshes."""
    cfg = get_config(arch)
    mesh = _abstract_mesh(multi_pod)
    ctx = ParallelContext(mesh=mesh)
    shapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, ctx, shapes)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "dbrx-132b"])
def test_expert_weights_are_expert_parallel(arch):
    """The paper's layout: expert dim sharded over `data` (EP==DP)."""
    cfg = get_config(arch)
    mesh = _abstract_mesh()
    ctx = ParallelContext(mesh=mesh)
    shapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, ctx, shapes)
    found = []

    def visit(path, spec):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if "experts" in names and names[-1] == "w_in":
            found.append(spec)

    jax.tree_util.tree_map_with_path(lambda p, s: visit(p, s), specs)
    assert found
    for spec in found:
        assert spec[1] == "data", spec    # stacked leaf: (repeats, E, d, f)
        assert spec[3] == "model", spec   # expert d_ff TP (paper footnote 1)


def test_cache_specs_decode_batch1_seq_sharded():
    """long_500k (batch=1): KV/seq sharding falls back sanely."""
    cfg = get_config("h2o-danube-3-4b")
    mesh = _abstract_mesh()
    ctx = ParallelContext(mesh=mesh)
    shapes = jax.eval_shape(lambda: init_cache(cfg, 1, 4096))
    specs = cache_specs(cfg, ctx, shapes)
    leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert leaves  # must produce specs without error


def test_state_specs_cover_opt_state():
    cfg = reduced(get_config("dbrx-132b"))
    mesh = _abstract_mesh()
    ctx = ParallelContext(mesh=mesh)
    tc = TrainConfig()
    shapes = jax.eval_shape(
        lambda: init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc))
    specs = state_specs(cfg, ctx, shapes)
    # moments share the param layout
    assert jax.tree_util.tree_structure(specs["opt"]["m"]) == \
        jax.tree_util.tree_structure(specs["params"])


def test_sharded_train_step_runs_and_matches_single_device():
    """Full sharded MoE train step on 8 simulated devices == CPU oracle."""
    out = run_py("""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig, GatingDropoutConfig
from repro.core.moe import ParallelContext
from repro.models import init_model
from repro.parallel.sharding import batch_specs, state_specs, to_shardings
from repro.training import init_train_state, make_train_step

cfg = reduced(get_config('dbrx-132b'))
moe = dataclasses.replace(cfg.moe, jitter_eps=0.0)
cfg = dataclasses.replace(cfg, moe=moe)
tc = TrainConfig(lr=1e-3, warmup_steps=10, seed=0)
key = jax.random.PRNGKey(0)
B, L = 8, 32
batch = {'tokens': jax.random.randint(key, (B, L), 3, cfg.vocab)}
batch['labels'] = jnp.roll(batch['tokens'], -1, 1)
batch['loss_mask'] = jnp.ones((B, L), jnp.float32)

params = init_model(key, cfg)
state_cpu = init_train_state(params, tc)
step_cpu = make_train_step(cfg, tc, None)
_, m_cpu = step_cpu(state_cpu, batch, False)

from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ('data', 'model'))
ctx = ParallelContext(mesh=mesh)
state = init_train_state(init_model(key, cfg), tc)
st_specs = to_shardings(mesh, state_specs(cfg, ctx, jax.eval_shape(lambda: state)))
b_specs = to_shardings(mesh, batch_specs(cfg, ctx, batch))
state = jax.device_put(state, st_specs)
batch = jax.device_put(batch, b_specs)
step = jax.jit(make_train_step(cfg, tc, ctx, jit=False),
               in_shardings=(st_specs, b_specs), static_argnums=(2,),
               out_shardings=(st_specs, None))
_, m = step(state, batch, False)
d = abs(float(m['loss']) - float(m_cpu['loss']))
print('loss_diff', d)
# CPU oracle routes over ONE capacity group; the 4-way EP shards route over
# four smaller groups, so capacity-boundary token drops differ slightly —
# a real semantic difference, not a numerics bug. Allow <1% of loss.
assert d < 0.07, d
print('OK')
""")
    assert "OK" in out
