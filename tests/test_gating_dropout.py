"""Gating Dropout semantics: consensus, rates, branch equivalence, and the
paper's core claim — the dropped executable contains NO all-to-all."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_py
from repro.configs.base import (GatingDropoutConfig, ModelConfig, MoEConfig)
from repro.core import (drop_decision, drop_decision_host, init_moe_params,
                        moe_oracle)
from repro.core.gating_dropout import (expected_alltoall_fraction,
                                       expected_expert_flop_fraction)


def test_decision_deterministic_consensus():
    """Every 'host' computing the decision from (seed, step) agrees — the
    TPU-native replacement for the paper's coordinator broadcast."""
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.3)
    for step in range(50):
        a = bool(drop_decision(gd, 7, step))
        b = drop_decision_host(gd, 7, step)
        assert a == b


def test_decision_rate_matches_p():
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.3)
    draws = [drop_decision_host(gd, 0, s) for s in range(2000)]
    assert abs(np.mean(draws) - 0.3) < 0.04


def test_batched_decisions_equal_per_step():
    """The one-dispatch batched draw (Trainer host_cond path) is bitwise
    the per-step draws, for any span and seed; disabled configs give all
    False without dispatching."""
    from repro.core.gating_dropout import drop_decisions_host
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.3)
    for seed, lo, hi in [(0, 0, 64), (7, 5, 6), (3, 100, 131)]:
        batched = drop_decisions_host(gd, seed, lo, hi)
        per_step = [drop_decision_host(gd, seed, i) for i in range(lo, hi)]
        np.testing.assert_array_equal(batched, per_step)
    off = GatingDropoutConfig(mode="off", rate=0.0)
    assert not drop_decisions_host(off, 0, 0, 16).any()
    assert drop_decisions_host(gd, 0, 4, 4).shape == (0,)


def test_decision_off_at_inference():
    gd = GatingDropoutConfig(mode="gate_drop", rate=1.0)
    assert not bool(drop_decision(gd, 0, 5, is_training=False))
    assert not drop_decision_host(gd, 0, 5, is_training=False)


def test_expected_fractions():
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.3)
    assert expected_alltoall_fraction(gd) == pytest.approx(0.7)
    assert expected_expert_flop_fraction(gd) == 1.0
    ged = GatingDropoutConfig(mode="gate_expert_drop", rate=0.2)
    assert expected_expert_flop_fraction(ged) == pytest.approx(0.8)


def _cfg(mode="gate_drop", rate=0.3, k=1, E=8):
    return ModelConfig(d_model=32, d_ff=64, vocab=64, moe=MoEConfig(
        n_experts=E, top_k=k, d_ff_expert=64, jitter_eps=0.0,
        gating_dropout=GatingDropoutConfig(mode=mode, rate=rate)))


def test_rate_zero_equals_baseline():
    cfg0 = _cfg(rate=0.0)
    p = init_moe_params(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y0, _ = moe_oracle(p, x, cfg0, decision=None)
    gd = cfg0.moe.gating_dropout
    for step in range(10):
        d = drop_decision_host(gd, 0, step)
        assert not d
        y, _ = moe_oracle(p, x, cfg0, decision=d)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y))


def test_traced_equals_static_branches():
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    for d in (False, True):
        y_static, _ = moe_oracle(p, x, cfg, ep=4, decision=d)
        y_traced, _ = moe_oracle(p, x, cfg, ep=4, decision=jnp.asarray(d))
        np.testing.assert_allclose(np.asarray(y_static),
                                   np.asarray(y_traced), atol=1e-6)


def test_gate_expert_drop_skips_layer():
    cfg = _cfg(mode="gate_expert_drop", rate=0.2)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y, aux = moe_oracle(p, x, cfg, ep=4, decision=True)
    assert np.abs(np.asarray(y)).max() == 0.0      # residual passthrough
    assert float(aux["balance"]) == 0.0


def test_expert_load_counts_all_k_slots():
    """Routed steps: load sums to exactly top_k (all k slots counted).
    Gate-Drop local steps report the same semantics restricted to slots
    that survived locally — sum <= top_k, equal when nothing drops, and
    ALWAYS > 1 for top_k=2 with ample capacity (the old slot-0-only
    counting capped the local sum at 1 and ignored capacity drops)."""
    cfg = _cfg(k=2, E=8)
    cfg = ModelConfig(d_model=32, d_ff=64, vocab=64, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, eval_capacity_factor=8.0))
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    _, aux_routed = moe_oracle(p, x, cfg, ep=4, decision=False)
    assert float(aux_routed["load"].sum()) == pytest.approx(2.0, abs=1e-5)
    _, aux_local = moe_oracle(p, x, cfg, ep=4, decision=True)
    s = float(aux_local["load"].sum())
    # ample capacity + 2 local experts per shard: both slots are locally
    # satisfiable, so parity with the routed-step sum holds
    assert s == pytest.approx(2.0, abs=1e-5)
    assert float(aux_local["dropped_frac"]) == pytest.approx(0.0, abs=1e-5)
    # with train capacity 1.0, drops appear and the sum is short exactly
    # by the dropped fraction of the k slots
    cfg_tight = ModelConfig(d_model=32, d_ff=64, vocab=64,
                            moe=dataclasses.replace(cfg.moe,
                                                    capacity_factor=1.0))
    _, aux_tight = moe_oracle(p, x, cfg_tight, ep=4, decision=True,
                              is_training=True)
    st = float(aux_tight["load"].sum())
    df = float(aux_tight["dropped_frac"])
    assert st == pytest.approx(2.0 * (1.0 - df), abs=1e-5)


def test_local_path_uses_only_local_experts():
    """Zero out the non-local experts: output must be unchanged on the
    dropped path (proves no token left its shard)."""
    cfg = _cfg(E=8)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    ep = 4
    y, _ = moe_oracle(p, x, cfg, ep=ep, decision=True)
    # shard s uses experts [2s, 2s+2); zeroing *other* shards' experts for
    # shard 0's tokens changes nothing
    import jax.tree_util as jtu
    p2 = jax.tree.map(lambda a: a.copy(), p)
    p2["experts"] = jax.tree.map(lambda a: a.at[2:].set(0.0), p["experts"])
    y2, _ = moe_oracle(p2, x, cfg, ep=ep, decision=True)
    T = 4 * 16 // ep   # tokens per virtual shard (flattened order)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 32)[:T],
                               np.asarray(y2).reshape(-1, 32)[:T], atol=1e-6)


def test_dropped_executable_has_no_alltoall():
    """THE paper claim, structurally: host_cond dropped executable contains
    zero all-to-all ops; the routed one contains them."""
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, GatingDropoutConfig
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ('data', 'model'))
ctx = ParallelContext(mesh=mesh)
cfg = ModelConfig(d_model=64, d_ff=128, vocab=100, moe=MoEConfig(
    n_experts=8, top_k=1, d_ff_expert=128,
    gating_dropout=GatingDropoutConfig(mode='gate_drop', rate=0.3,
                                       strategy='host_cond')))
p = init_moe_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
for dec, name in [(False, 'routed'), (True, 'dropped')]:
    txt = jax.jit(lambda p, x: moe_sharded(
        p, x, cfg, ctx, rng=jax.random.PRNGKey(2), decision=dec)
    ).lower(p, x).compile().as_text()
    print(name, txt.count('all-to-all'))
""")
    lines = dict(l.split() for l in out.strip().splitlines())
    assert int(lines["routed"]) > 0
    assert int(lines["dropped"]) == 0


def test_sharded_matches_oracle_all_branches():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, GatingDropoutConfig
from repro.core import init_moe_params, moe_oracle, moe_sharded, ParallelContext
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ('data', 'model'))
ctx = ParallelContext(mesh=mesh)
cfg = ModelConfig(d_model=64, d_ff=128, vocab=100, moe=MoEConfig(
    n_experts=8, top_k=2, d_ff_expert=128, capacity_factor=1.5,
    gating_dropout=GatingDropoutConfig(mode='gate_drop', rate=0.3)))
key = jax.random.PRNGKey(0)
p = init_moe_params(key, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
for dec in (None, True, False):
    y_ref, aux_ref = moe_oracle(p, x, cfg, ep=4, rng=key, decision=dec)
    y_sh, aux_sh = jax.jit(lambda p, x: moe_sharded(
        p, x, cfg, ctx, rng=key, decision=dec))(p, x)
    d = float(jnp.abs(y_ref - y_sh).max())
    db = abs(float(aux_ref['balance']) - float(aux_sh['balance']))
    print('diff', d, db)
    assert d < 2e-5 and db < 1e-5, (dec, d, db)
print('OK')
""")
    assert "OK" in out
