"""Differential kernel-testing layer for the fused MoE megakernel
(DESIGN.md §11): seeded parity sweeps against the pure-jnp oracle
pipeline, degenerate-case coverage, finite-difference gradient checks for
every custom-VJP kernel, and the token_valid slot-masking regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.configs import get_config, reduced
from repro.configs.base import MoEConfig
from repro.core import get_backend, init_moe_params
from repro.core import router as R
from repro.kernels import combine, dispatch, grouped_matmul, ops, ref
from repro.kernels.moe_megakernel import fused_moe_ffn

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(0)


def oracle_moe(x, info, w_in, w_gate, w_out, E, cap, act="silu"):
    """The unfused reference: router dispatch -> einsum FFN -> combine."""
    buf = R.dispatch(x, info, E, cap)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        h = actf(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = actf(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, w_out)
    return R.combine(out, info)


def make_case(E, k, cap, T, d, f, dtype=jnp.float32, gated=True, seed=0):
    moe = MoEConfig(n_experts=E, top_k=k, jitter_eps=0.0)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (T, d), dtype)
    wr = jax.random.normal(ks[1], (d, E))
    w_in = (jax.random.normal(ks[2], (E, d, f)) * 0.1).astype(dtype)
    w_gate = ((jax.random.normal(ks[3], (E, d, f)) * 0.1).astype(dtype)
              if gated else None)
    w_out = (jax.random.normal(ks[4], (E, f, d)) * 0.1).astype(dtype)
    rr = R.route(wr, x.astype(jnp.float32), moe, is_training=False)
    info = R.dispatch_info(rr, E, cap)
    return x, info, w_in, w_gate, w_out


# ---------------------------------------------------------------------------
# forward parity sweep (incl. degenerate shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,k,cap,T,d,f", [
    (4, 2, 16, 64, 32, 48),
    (8, 1, 8, 64, 16, 32),      # k=1
    (2, 2, 4, 32, 64, 64),      # heavy capacity drops
    (4, 1, 1, 32, 16, 16),      # capacity=1
    (4, 2, 8, 37, 24, 40),      # T, d, f with no friendly divisors
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_oracle_sweep(E, k, cap, T, d, f, dtype):
    x, info, w_in, w_gate, w_out = make_case(E, k, cap, T, d, f, dtype)
    y = ops.fused_moe_op(x, info, w_in, w_gate, w_out, E, cap,
                         interpret=True)
    y_ref = oracle_moe(x, info, w_in, w_gate, w_out, E, cap)
    assert y.dtype == x.dtype
    atol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


@pytest.mark.parametrize("gated", [True, False])
def test_fused_ungated_and_gelu_variants(gated):
    x, info, w_in, w_gate, w_out = make_case(4, 2, 8, 48, 32, 32,
                                             gated=gated)
    for act in ("silu", "gelu"):
        y = ops.fused_moe_op(x, info, w_in, w_gate, w_out, 4, 8, act,
                             interpret=True)
        y_ref = oracle_moe(x, info, w_in, w_gate, w_out, 4, 8, act)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5)


def test_fused_all_tokens_dropped_is_zero():
    """keep == 0 everywhere (every routing choice masked) -> exact zeros."""
    x, info, w_in, w_gate, w_out = make_case(4, 2, 8, 32, 16, 16)
    info = info._replace(keep=jnp.zeros_like(info.keep))
    y = ops.fused_moe_op(x, info, w_in, w_gate, w_out, 4, 8, interpret=True)
    assert float(jnp.abs(y).max()) == 0.0


def test_fused_block_size_invariance():
    """Output must not depend on the f-block tiling."""
    x, info, w_in, w_gate, w_out = make_case(4, 2, 8, 48, 32, 64)
    tables = ops.routing_tables(info, 4, 8)
    args = (x, w_in, w_gate, w_out, info.topk_w, info.keep,
            tables.slot_token, tables.slot_valid, tables.token_slot)
    y1 = fused_moe_ffn(*args, bf=64, interpret=True)
    y2 = fused_moe_ffn(*args, bf=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


# ---------------------------------------------------------------------------
# backend-level parity: outputs AND aux
# ---------------------------------------------------------------------------

def _backend_pair(cfg, x, token_valid=None, decision=False):
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for name in ("oracle", "pallas_fused"):
        out[name] = get_backend(name)(
            p, x, cfg, None, rng=jax.random.PRNGKey(7), decision=decision,
            is_training=True, token_ids=None, token_valid=token_valid)
    return out["oracle"], out["pallas_fused"]


@pytest.mark.parametrize("decision", [False, True])
def test_backend_parity_outputs_and_aux(decision):
    cfg = reduced(get_config("zcode-m3-base"))
    x = jax.random.normal(KEY, (4, 32, cfg.d_model))
    (yo, ao), (yf, af) = _backend_pair(cfg, x, decision=decision)
    np.testing.assert_allclose(np.asarray(yo), np.asarray(yf), atol=5e-6)
    # aux must be backend-invariant: same drops, same expert load
    np.testing.assert_allclose(np.asarray(ao["dropped_frac"]),
                               np.asarray(af["dropped_frac"]), atol=0)
    np.testing.assert_allclose(np.asarray(ao["load"]),
                               np.asarray(af["load"]), atol=0)
    np.testing.assert_allclose(np.asarray(ao["balance"]),
                               np.asarray(af["balance"]), atol=1e-6)


def test_backend_token_valid_slot_masking_regression():
    """Serving slot masks must be honored by the megakernel gather:
    retired rows produce EXACT zeros, stay out of expert-capacity
    competition (their slots go to live tokens), and the fused backend
    agrees with oracle under the same mask."""
    cfg = reduced(get_config("zcode-m3-base"))
    B, L = 4, 32
    x = jax.random.normal(KEY, (B, L, cfg.d_model))
    tv = jnp.ones((B, L), bool).at[1].set(False).at[3].set(False)
    (yo, ao), (yf, af) = _backend_pair(cfg, x, token_valid=tv)
    np.testing.assert_allclose(np.asarray(yo), np.asarray(yf), atol=5e-6)
    np.testing.assert_allclose(np.asarray(ao["dropped_frac"]),
                               np.asarray(af["dropped_frac"]), atol=0)
    # retired rows contribute nothing
    assert float(jnp.abs(yf[1]).max()) == 0.0
    assert float(jnp.abs(yf[3]).max()) == 0.0


def test_token_valid_vacates_capacity_slots():
    """Masked rows must not occupy expert-buffer slots: with the front
    half of the batch retired, valid tokens that lost the capacity race
    in the unmasked run now win slots (DESIGN.md §11 index-table
    contract — masking folds into keep, which drives the tables the
    kernel gathers from)."""
    moe = MoEConfig(n_experts=2, top_k=1, jitter_eps=0.0)
    T, d, cap = 16, 8, 4
    x = jax.random.normal(KEY, (T, d))
    wr = jax.random.normal(jax.random.PRNGKey(1), (d, moe.n_experts))
    rr = R.route(wr, x, moe, is_training=False)
    full = R.dispatch_info(rr, moe.n_experts, cap)
    mask = jnp.ones((T, 1), bool).at[:8].set(False)
    msk = R.dispatch_info(rr, moe.n_experts, cap, valid=mask)
    # masked rows never hold a slot
    assert int(msk.keep[:8].sum()) == 0
    # the unmasked run was capacity-bound: back-half tokens all lost
    assert int(full.keep.sum()) == moe.n_experts * cap
    assert int(full.keep[8:].sum()) == 0
    # ...and with the front half retired, those same tokens win slots
    assert int(msk.keep[8:].sum()) > 0


def test_backend_grad_parity_under_jit():
    cfg = reduced(get_config("zcode-m3-base"))
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))

    def loss(name):
        fn = get_backend(name)

        def l(p_, x_):
            y, _ = fn(p_, x_, cfg, None, rng=jax.random.PRNGKey(3),
                      decision=False, is_training=True, token_ids=None)
            return (y.astype(jnp.float32) ** 2).mean()

        return jax.jit(jax.grad(l))(p, x)

    go, gf = loss("oracle"), loss("pallas_fused")
    for a, b in zip(jax.tree.leaves(go), jax.tree.leaves(gf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


# ---------------------------------------------------------------------------
# finite-difference gradient checks for every custom-VJP kernel
# ---------------------------------------------------------------------------

def _tables(E=4, k=2, cap=8, T=24, d=16):
    moe = MoEConfig(n_experts=E, top_k=k, jitter_eps=0.0)
    x = jax.random.normal(KEY, (T, d))
    wr = jax.random.normal(jax.random.PRNGKey(1), (d, E))
    rr = R.route(wr, x, moe, is_training=False)
    info = R.dispatch_info(rr, E, cap)
    return x, info, ops.routing_tables(info, E, cap)


def test_check_grads_dispatch():
    x, _, t = _tables()
    check_grads(lambda x_: dispatch(x_, t.slot_token, t.slot_valid,
                                    interpret=True),
                (x,), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)


def test_check_grads_combine():
    x, info, t = _tables()
    buf = dispatch(x, t.slot_token, t.slot_valid, interpret=True)
    check_grads(lambda b, w: combine(b, t.token_slot, w, info.keep,
                                     interpret=True),
                (buf, info.topk_w), order=1, modes=["rev"],
                atol=1e-2, rtol=1e-2)


def test_check_grads_grouped_matmul():
    x = jax.random.normal(KEY, (2, 16, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16)) * 0.3
    check_grads(lambda a, b: grouped_matmul(a, b, interpret=True),
                (x, w), order=1, modes=["rev"], atol=1e-2, rtol=1e-2)


def test_check_grads_megakernel():
    x, info, t = _tables()
    E, cap, d, f = 4, 8, 16, 16
    w_in = jax.random.normal(jax.random.PRNGKey(2), (E, d, f)) * 0.1
    w_g = jax.random.normal(jax.random.PRNGKey(3), (E, d, f)) * 0.1
    w_out = jax.random.normal(jax.random.PRNGKey(4), (E, f, d)) * 0.1

    def fn(x_, wi, wg, wo, tw):
        return fused_moe_ffn(x_, wi, wg, wo, tw, info.keep, t.slot_token,
                             t.slot_valid, t.token_slot, interpret=True)

    check_grads(fn, (x, w_in, w_g, w_out, info.topk_w), order=1,
                modes=["rev"], atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# flash decode: per-row (slot-pool) index form
# ---------------------------------------------------------------------------

def test_flash_decode_per_row_index():
    """Each batch row masked at its OWN depth — the slot-pool contract."""
    from repro.kernels import flash_decode
    b, h, kv, hd, s = 4, 4, 2, 32, 256
    q = jax.random.normal(KEY, (b, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    idx = jnp.array([0, 17, 128, 255], jnp.int32)
    o = flash_decode(q, k, v, idx, bs=64, interpret=True)
    o_ref = ref.flash_decode_ref(q, k, v, idx)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)
    # row i must match a scalar-index call at idx[i]
    for i in range(b):
        oi = flash_decode(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                          int(idx[i]), bs=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(oi[0]),
                                   atol=2e-5)
