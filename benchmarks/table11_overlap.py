"""Beyond paper — Table 11: overlapped (micro-chunked) expert dispatch.

Sweeps the §14 overlapped substrates x chunk count two ways:

  * REAL 8-device mesh (simulated CPU devices, `moe_sharded`): per cell
    the routed forward is compiled and run, and the §10/§14 three-way
    invariant is ASSERTED — in-graph telemetry == analytic cost model ==
    all-to-all ops parsed from the compiled HLO (calls/bytes exact, wire
    < 1 B), with exposed + hidden == wire. Output parity is pinned
    BITWISE: every non-compressed overlapped cell equals dense, every
    compressed one equals the unchunked compressed reference, at every
    chunk count. The host_cond dropped chunk executable stays
    zero-collective under the maximal overlapped composition.

  * MODELED production cell (pure math — simulated-CPU wall time cannot
    show communication overlap, the collectives are memcpys): expert-FFN
    compute priced from analytic FLOPs at the TPU v5e peak
    (`benchmarks/common.py::TPU_V5E`), wire priced by the two-tier
    `Topology` bandwidths, and the n-chunk schedule priced by the FIFO
    two-resource `pipeline_time` model. At the paper-ish shape (d_model
    1024, d_ff 4096, f32 wire) the wire/compute time ratio is ~1.1, so
    the double-buffered pipeline hides most of the exchange.

Acceptance bars (asserted):
  * overlapped >= 1.25x dense routed-step throughput (modeled) at the
    best chunk count, at BITWISE-identical outputs (real mesh);
  * exposed wire <= 50%% of total wire at that chunk count;
  * telemetry == parsed HLO == cost model for every real cell;
  * total bytes/wire EXACTLY equal dense at every chunk count (chunking
    multiplies calls, never bytes);
  * dropped chunk executable: zero all-to-alls.

Writes benchmarks/artifacts/table11_overlap.json.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ART, TPU_V5E, csv_row, run_subprocess

SUBSTRATES = ("dense", "compressed", "overlapped", "overlapped_hierarchical",
              "overlapped_compressed", "overlapped_hierarchical_compressed")
N_CHUNKS = (1, 2, 4)

_WORKER = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig, TrainConfig)
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.comm import layer_cost
from repro.data import LMTaskConfig, SyntheticLM, stack_batches
from repro.analysis import parse_collectives
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.training import init_train_state, make_chunk_step

SUBSTRATES = %(substrates)s
N_CHUNKS = %(n_chunks)s

mesh = make_mesh((8,), ('data',))
ctx = ParallelContext(mesh=mesh)

def build(substrate, n_chunks):
    return ModelConfig(
        d_model=64, d_ff=128, vocab=256, n_layers=1, n_heads=2, n_kv_heads=2,
        remat=False, dtype='float32', param_dtype='float32',
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=128,
                      backend='sharded',
                      comm=CommConfig(substrate=substrate, n_chunks=n_chunks),
                      gating_dropout=GatingDropoutConfig(
                          mode='gate_drop', rate=0.3, strategy='host_cond')))

cfg0 = build('dense', 1)
p = init_moe_params(jax.random.PRNGKey(0), cfg0)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
out, ys = {}, {}
for sub in SUBSTRATES:
    for n in N_CHUNKS:
        cfg = build(sub, n)
        f = jax.jit(lambda p_, x_: moe_sharded(p_, x_, cfg, ctx, rng=None,
                                               decision=False))
        colls = parse_collectives(f.lower(p, x).compile().as_text()
                                  ).get('all-to-all', {'count': 0, 'bytes': 0,
                                                       'wire_bytes': 0})
        y, aux = f(p, x)
        ys[(sub, n)] = np.asarray(y)
        tele = {k: float(aux[k]) for k in
                ('comm_a2a_calls', 'comm_bytes', 'comm_wire_bytes',
                 'comm_exposed_bytes', 'comm_hidden_bytes')}
        c = layer_cost(cfg, tokens_per_shard=16, ep=8)
        # telemetry == parsed HLO == cost model, per cell (the §14 bar)
        assert tele['comm_a2a_calls'] == colls['count'] == c['calls'], \
            (sub, n, tele, colls, c)
        assert tele['comm_bytes'] == colls['bytes'] == c['bytes'], \
            (sub, n, tele, colls, c)
        assert abs(tele['comm_wire_bytes'] - colls['wire_bytes']) < 1 \
            and abs(tele['comm_wire_bytes'] - c['wire_bytes']) < 1, \
            (sub, n, tele, colls, c)
        assert (tele['comm_exposed_bytes'] + tele['comm_hidden_bytes']
                == tele['comm_wire_bytes']), (sub, n, tele)
        # chunking multiplies CALLS only: bytes/wire == the n=1 exchange
        base = out.get(f'{sub}@1')
        if base is not None:
            assert tele['comm_bytes'] == base['telemetry']['comm_bytes'], \
                (sub, n)
            assert (tele['comm_wire_bytes']
                    == base['telemetry']['comm_wire_bytes']), (sub, n)
        # wall time of the compiled forward (context only: simulated-CPU
        # collectives are memcpys, overlap cannot show up here)
        f(p, x)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            r = f(p, x)[0]
        r.block_until_ready()
        out[f'{sub}@{n}'] = {'telemetry': tele, 'hlo': colls,
                             'fwd_us': (time.perf_counter() - t0) / 5 * 1e6}

# bitwise parity: every overlapped cell == its base substrate's output
for (sub, n), y in ys.items():
    ref = ys[('compressed', 1) if 'compressed' in sub else ('dense', 1)]
    assert np.array_equal(y, ref), (sub, n, 'not bitwise base substrate')

# dropped chunk executable: zero collectives under the maximal composition
cfg = build('overlapped_hierarchical_compressed', 4)
tc = TrainConfig(lr=1e-3, warmup_steps=4, seed=0)
task = SyntheticLM(LMTaskConfig(vocab=256, seq_len=16))
batches = {k: jnp.asarray(v) for k, v in
           stack_batches(lambda i: task.sample_batch(i, 8), 0, 3).items()}
state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
chunk = make_chunk_step(cfg, tc, ctx, jit=False)
txts = {dec: jax.jit(chunk, static_argnums=(2,)).lower(
    state, batches, dec).compile().as_text() for dec in (False, True)}
assert txts[False].count('all-to-all') > 0
assert txts[True].count('all-to-all') == 0, 'dropped chunk has all-to-all'
out['dropped_a2a_ops'] = txts[True].count('all-to-all')
out['bitwise_vs_base'] = True
print(json.dumps(out))
"""

# modeled production cell: one routed layer, paper-ish shape, f32 wire
_E, _CAP, _D, _DFF, _ISZ, _EP = 8, 1024, 1024, 4096, 4, 8


def _modeled_sweep():
    """Pure cost-model math: per (substrate, n_chunks), the serial vs
    FIFO-pipelined step time of one routed layer at TPU v5e compute and
    the two-tier Topology wire rates."""
    from repro.comm import (effective_chunks, pipeline_time, transport_cost,
                            transport_time)
    from repro.configs.base import CommConfig, Topology
    top = Topology()
    # gated expert FFN: 3 grouped matmuls x 2 FLOPs over the dispatched
    # (E*cap, d) rows -> per-device compute the pipeline can hide behind
    compute_s = 6.0 * _E * _CAP * _D * _DFF / TPU_V5E.flops
    rows = {}
    for sub in SUBSTRATES:
        for n in (1, 2, 4, 8, 16):
            comm = CommConfig(substrate=sub, n_chunks=n)
            if not comm.overlapped and n > 1:
                continue
            c = transport_cost(comm, ep=_EP, n_experts=_E, cap=_CAP,
                               d_model=_D, itemsize=_ISZ)
            t = transport_time(c, top)
            n_eff = effective_chunks(_CAP, n) if comm.overlapped else 1
            step_s = pipeline_time(compute_s, t["comm_s"], n_eff)
            rows[f"{sub}@{n}"] = {
                "n_eff": n_eff, "comm_s": t["comm_s"],
                "exposed_s": t["exposed_s"],
                "exposed_frac": (c["exposed_wire_bytes"] / c["wire_bytes"]
                                 if c["wire_bytes"] else 1.0),
                "wire_bytes": c["wire_bytes"], "step_s": step_s,
                "steps_s": 1.0 / step_s}
    return compute_s, rows


def main(fast: bool = True):
    res = json.loads(run_subprocess(_WORKER % {
        "substrates": repr(SUBSTRATES), "n_chunks": repr(N_CHUNKS)}
        ).strip().splitlines()[-1])

    compute_s, modeled = _modeled_sweep()
    dense = modeled["dense@1"]
    best_name, best = None, None
    for name, r in modeled.items():
        if name.startswith("overlapped@"):
            if best is None or r["steps_s"] > best["steps_s"]:
                best_name, best = name, r
        r["speedup_vs_dense"] = r["steps_s"] * dense["step_s"]

    # acceptance: the pipeline buys >= 1.25x the dense routed step at
    # bitwise-identical outputs, exposing <= half the wire
    assert res["bitwise_vs_base"] is True
    assert best["speedup_vs_dense"] >= 1.25, (best_name, best)
    assert best["exposed_frac"] <= 0.5, (best_name, best)
    assert best["wire_bytes"] == dense["wire_bytes"], (best_name, best)

    for name, r in sorted(modeled.items()):
        csv_row(f"table11/{name}", r["step_s"] * 1e6,
                f"steps_s={r['steps_s']:.1f};"
                f"speedup={r['speedup_vs_dense']:.2f}x;"
                f"exposed_frac={r['exposed_frac']:.2f};"
                f"n_eff={r['n_eff']}")
    csv_row("table11/best", best["step_s"] * 1e6,
            f"{best_name};speedup={best['speedup_vs_dense']:.2f}x;"
            f"exposed_frac={best['exposed_frac']:.2f}")

    out = {
        "real_mesh": res,
        "modeled": modeled,
        "best": {"cell": best_name, **best},
        "config": {
            "mesh": "8x data (simulated CPU)", "real_tokens_per_shard": 16,
            "modeled_shape": {"n_experts": _E, "cap": _CAP, "d_model": _D,
                              "d_ff_expert": _DFF, "itemsize": _ISZ,
                              "ep": _EP},
            "compute_s_per_layer": compute_s,
            "hw": TPU_V5E.desc,
            "note": "throughput modeled (v5e FLOPs + two-tier Topology "
                    "wire + FIFO pipeline): simulated-CPU collectives "
                    "are memcpys, so real-mesh cells pin bitwise parity "
                    "and telemetry==HLO==cost instead of wall time"}}
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table11_overlap.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
