"""Beyond paper — Table 9: communication substrates for expert dispatch.

Sweeps the comm substrate registry (DESIGN.md §10) x gating-dropout rate
on a REAL 8-device mesh (simulated CPU devices, `moe_sharded`, host_cond
gating dropout for the structural claims, traced_cond for the timed
runs):

  {dense, hierarchical, compressed, hierarchical_compressed} x {0, 0.3}

and reports, per cell: trained steps/s, final loss, and the bytes the
wire actually moved — measured three independent ways that must agree:

  * in-graph telemetry summed over the run's history records;
  * the analytic model (`comm/cost.py`);
  * all-to-all ops parsed from the compiled routed-step HLO.

Acceptance bars (asserted):
  * compressed dispatch moves <= 0.5x the wire bytes of dense;
  * hierarchical is BITWISE dense (same permutation -> identical loss);
  * compressed trains to loss parity with dense within ``LOSS_RTOL``;
  * telemetry == cost model == HLO for every substrate;
  * the host_cond DROPPED chunk executable contains zero all-to-alls
    under every substrate (the paper's claim survives every wire).

Writes benchmarks/artifacts/table9_comm.json.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ART, csv_row, run_subprocess

SUBSTRATES = ("dense", "hierarchical", "compressed",
              "hierarchical_compressed")
LOSS_RTOL = 0.02          # compressed-vs-dense final-loss parity tolerance

_WORKER = r"""
import json, time
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (CommConfig, GatingDropoutConfig, ModelConfig,
                                MoEConfig, TrainConfig)
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.core.moe import _select_branch
from repro.comm import layer_cost
from repro.data import LMTaskConfig, SyntheticLM, stack_batches
from repro.analysis import parse_collectives
from repro.launch.mesh import make_mesh
from repro.models import init_model
from repro.training import Trainer, init_train_state, make_chunk_step
from repro.training.steps import n_moe_layers

STEPS, CHUNK, BATCH, SEQ = %(steps)d, 8, 8, 16
RATES = %(rates)s
SUBSTRATES = %(substrates)s

mesh = make_mesh((8,), ('data',))
ctx = ParallelContext(mesh=mesh)

def build(substrate, rate, strategy):
    return ModelConfig(
        d_model=64, d_ff=128, vocab=256, n_layers=2, n_heads=2, n_kv_heads=2,
        remat=False, dtype='float32', param_dtype='float32',
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                      backend='sharded', comm=CommConfig(substrate=substrate),
                      gating_dropout=GatingDropoutConfig(
                          mode='gate_drop', rate=rate, strategy=strategy)))

task = SyntheticLM(LMTaskConfig(vocab=256, seq_len=SEQ))
batch_fn = lambda i: task.sample_batch(i, BATCH)
out = {}

# ---- per-substrate structural checks (rate-independent) -------------------
cfg0 = build('dense', 0.3, 'host_cond')
p0 = init_moe_params(jax.random.PRNGKey(0), cfg0)
x0 = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
for sub in SUBSTRATES:
    cfg = build(sub, 0.3, 'host_cond')
    # (a) telemetry == cost model == HLO on the routed sharded forward
    f = jax.jit(lambda p_, x_: moe_sharded(p_, x_, cfg, ctx, rng=None,
                                           decision=False))
    colls = parse_collectives(f.lower(p0, x0).compile().as_text()
                              ).get('all-to-all', {})
    _, aux = f(p0, x0)
    tele = {k: float(aux[k]) for k in
            ('comm_a2a_calls', 'comm_bytes', 'comm_wire_bytes')}
    c = layer_cost(cfg, tokens_per_shard=16, ep=8)
    assert tele['comm_a2a_calls'] == colls['count'] == c['calls'], (sub, tele, colls, c)
    assert tele['comm_bytes'] == colls['bytes'] == c['bytes'], (sub, tele, colls, c)
    assert abs(tele['comm_wire_bytes'] - colls['wire_bytes']) < 1 \
        and abs(tele['comm_wire_bytes'] - c['wire_bytes']) < 1, (sub, tele, colls, c)
    # (b) host_cond dropped chunk executable: ZERO all-to-alls
    tc = TrainConfig(lr=1e-3, warmup_steps=4, seed=0)
    batches = {k: jnp.asarray(v)
               for k, v in stack_batches(batch_fn, 0, 3).items()}
    state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
    chunk = make_chunk_step(cfg, tc, ctx, jit=False)
    txts = {dec: jax.jit(chunk, static_argnums=(2,)).lower(
        state, batches, dec).compile().as_text() for dec in (False, True)}
    assert txts[False].count('all-to-all') > 0, sub
    assert txts[True].count('all-to-all') == 0, \
        f'{sub}: dropped executable contains all-to-all'
    out[sub] = {'telemetry_fwd': tele, 'hlo_fwd': colls,
                'cost_model_fwd': c,
                'dropped_a2a_ops': txts[True].count('all-to-all')}

# ---- timed sweep ----------------------------------------------------------
for sub in SUBSTRATES:
    for rate in RATES:
        cfg = build(sub, rate, 'traced_cond')
        tc = TrainConfig(lr=1e-3, warmup_steps=4, steps=STEPS, seed=0)
        tr = Trainer(cfg, tc, batch_fn, ctx=ctx, chunk=CHUNK,
                     strategy='traced_cond', log=None, log_every=1)
        _, hist = tr.run()
        first = next(r for r in hist if r['step'] == CHUNK - 1)
        sps = (STEPS - CHUNK) / max(hist[-1]['time_s'] - first['time_s'],
                                    1e-9)
        wire = sum(r['comm_wire_bytes'] for r in hist)  # log_every=1: all
        out[f'{sub}@{rate}'] = {
            'steps_s': sps, 'final_loss': hist[-1]['loss'],
            'wire_bytes_total': wire,
            'wire_bytes_per_step': wire / STEPS,
            'routed_frac': sum(r['comm_wire_bytes'] > 0 for r in hist)
                           / len(hist)}
print(json.dumps(out))
"""


def main(fast: bool = True):
    steps = 24 if fast else 48
    rates = (0.0, 0.3)
    res = json.loads(run_subprocess(_WORKER % {
        "steps": steps, "rates": repr(tuple(rates)),
        "substrates": repr(SUBSTRATES)}).strip().splitlines()[-1])

    dense0 = res["dense@0.0"]
    for rate in rates:
        d = res[f"dense@{rate}"]
        for sub in SUBSTRATES:
            r = res[f"{sub}@{rate}"]
            ratio = (r["wire_bytes_per_step"] / d["wire_bytes_per_step"]
                     if d["wire_bytes_per_step"] else 0.0)
            # acceptance: compressed moves <= 0.5x dense at loss parity
            if sub.endswith("compressed"):
                assert ratio <= 0.5, (sub, rate, ratio)
                rel = (abs(r["final_loss"] - d["final_loss"])
                       / max(abs(d["final_loss"]), 1e-9))
                assert rel <= LOSS_RTOL, \
                    f"{sub}@{rate}: loss {r['final_loss']} vs dense " \
                    f"{d['final_loss']} (rel {rel:.3f} > {LOSS_RTOL})"
            if sub == "hierarchical":
                # same permutation, bitwise: losses must be identical
                assert r["final_loss"] == d["final_loss"], (r, d)
            csv_row(f"table9/{sub}@gd{rate}", 1e6 / r["steps_s"],
                    f"steps_s={r['steps_s']:.2f};"
                    f"wire_B_per_step={r['wire_bytes_per_step']:.0f};"
                    f"vs_dense={ratio:.2f}x;"
                    f"loss={r['final_loss']:.4f};"
                    f"routed_frac={r['routed_frac']:.2f}")
    # gating dropout frees wire on top of any substrate: totals must drop
    for sub in SUBSTRATES:
        assert (res[f"{sub}@0.3"]["wire_bytes_total"]
                < res[f"{sub}@0.0"]["wire_bytes_total"]), sub
        assert res[f"{sub}@0.0"]["routed_frac"] == 1.0, sub
    res["config"] = {"steps": steps, "rates": list(rates),
                     "mesh": "8x data (simulated CPU)", "chunk": 8,
                     "batch": 8, "seq": 16, "loss_rtol": LOSS_RTOL,
                     "dense_wire_bytes_per_step":
                         dense0["wire_bytes_per_step"]}
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table9_comm.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
