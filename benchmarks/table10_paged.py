"""Table 10 (beyond paper): paged KV cache vs slot pool at equal memory.

The paged-KV claim (DESIGN.md §13): at EQUAL pinned KV-cache memory, the
block-table scheduler sustains >= 1.5x the concurrency of the slot pool
on the table8 long-tail trace. A slot pool pins one full ``max_seq`` row
per concurrent request, so its concurrency IS its memory budget; the
page arena allocates per-page, so short requests (75% of the long-tail
trace) stop stranding the tail of their rows and the freed pages admit
more requests.

Measured per arch (table8's narrowed reduced configs):

  * slot  -- `ContinuousScheduler` with ``mem_slots`` slots: the memory
             budget baseline (``mem_slots`` full cache rows).
  * paged -- `PagedScheduler` with an arena of ``mem_slots`` full-length
             requests' worth of pages (equal pageable-leaf bytes,
             asserted) and a concurrency cap of ``paged_slots`` — page
             availability, not slot count, is the binding constraint.
  * paged_noshare -- prefix caching off; bitwise token equality with the
             shared run is asserted (sharing must be invisible).

Sustained concurrency = mean live slots per decode tick
(``scheduler.alive_log``). Bitwise per-request greedy parity is asserted
in-benchmark for EVERY request across slot / paged / paged_noshare /
one-shot ``generate``. The trace shares a common prompt prefix across
half the requests so the prefix cache takes real hits (reported as
``prefix_hit_rate``). Results land in
``benchmarks/artifacts/table10_paged.json`` (schema: benchmarks/
README.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import ART, csv_row
from benchmarks.table8_serving import _bench_cfg, _extras
from repro.configs import PagedKVConfig
from repro.models import init_model
from repro.serve import (ContinuousScheduler, GenerateConfig, PagedScheduler,
                         Request, generate, paged_kv_bytes)
from repro.serve.paged import _cache_page_axes

ARCHS = ["yi-6b", "zcode-m3-base"]
KEY = jax.random.PRNGKey(0)


def make_trace(cfg, key, n: int, lens: List[int], max_new: int,
               prefix_len: int) -> List[Request]:
    """table8's long-tail trace (backlogged, 75% short budgets) with one
    twist: every even-rid request starts with the same ``prefix_len``
    token prefix, so consecutive admissions hit the prefix cache."""
    rs = np.random.RandomState(7)
    common = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 9999), (prefix_len,), 3, cfg.vocab),
        np.int32)
    reqs = []
    for i in range(n):
        plen = lens[i % len(lens)]
        if rs.rand() < 0.75:
            budget = int(rs.randint(2, 9))
        else:
            budget = int(rs.randint(max(2, max_new - 8), max_new + 1))
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 3, cfg.vocab), np.int32)
        if i % 2 == 0 and plen > prefix_len:
            toks = np.concatenate([common, toks[prefix_len:]])
        reqs.append(Request(
            rid=i, tokens=toks, max_new=budget, arrival=0.0,
            extras=_extras(cfg, jax.random.fold_in(key, 1000 + i))))
    return reqs


def _pageable_bytes(pool, cfg) -> int:
    """Bytes of the seq-tracking leaves of a SLOT pool — what the paged
    arena replaces (same structural discovery as `paged_kv_bytes`)."""
    _, seq = _cache_page_axes(cfg)
    return int(sum(jax.tree.leaves(jax.tree.map(
        lambda leaf, as_: leaf.size * leaf.dtype.itemsize if as_ >= 0
        else 0, pool, seq))))


def _serve(sched, reqs):
    t0 = time.perf_counter()
    results = sched.run([dataclasses.replace(r) for r in reqs])
    wall = time.perf_counter() - t0
    toks = {r.rid: r.tokens for r in results}
    n_tok = int(sum(r.length for r in results))
    alive = float(np.mean(sched.alive_log)) if sched.alive_log else 0.0
    return toks, n_tok, wall, alive


def bench_arch(arch: str, *, n_req: int, mem_slots: int, paged_slots: int,
               page_size: int, max_new: int, lens: List[int],
               buckets) -> Dict:
    cfg = _bench_cfg(arch)
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=max_new, eos_id=-1)
    reqs = make_trace(cfg, jax.random.fold_in(KEY, 2), n_req, lens,
                      max_new, prefix_len=page_size)

    def slot_sched():
        return ContinuousScheduler(params, cfg, gen, n_slots=mem_slots,
                                   prefill_buckets=buckets)

    def paged_sched(share: bool):
        return PagedScheduler(
            params, cfg, gen, n_slots=paged_slots, prefill_buckets=buckets,
            paged=PagedKVConfig(page_size=page_size,
                                n_slots_equiv=mem_slots,
                                prefix_caching=share))

    # warmup replays (compiles), then the measured replay
    _serve(slot_sched(), reqs)
    s = slot_sched()
    s_toks, n_tok, s_wall, s_alive = _serve(s, reqs)
    _serve(paged_sched(True), reqs)
    p = paged_sched(True)
    p_toks, _, p_wall, p_alive = _serve(p, reqs)
    u = paged_sched(False)
    u_toks, _, _, _ = _serve(u, reqs)

    # equal-memory check: the arena's pageable bytes must not exceed what
    # the slot pool pins for the same leaves (scratch page << scratch row)
    slot_bytes = _pageable_bytes(s.pool, cfg)
    arena_bytes = paged_kv_bytes(p.pool, cfg)
    assert arena_bytes <= slot_bytes, (arena_bytes, slot_bytes)

    # bitwise parity: every request, all four paths
    gref = dataclasses.replace(gen, max_seq=s.max_seq)
    for r in reqs:
        batch = {"tokens": r.tokens[None]}
        for k, v in r.extras.items():
            batch[k] = v[None]
        one = generate(params, batch, cfg, gref)
        n = min(int(one.lengths[0]), r.max_new)
        ref = np.asarray(one.tokens)[0, :n]
        assert np.array_equal(s_toks[r.rid], ref), (arch, "slot", r.rid)
        assert np.array_equal(p_toks[r.rid], ref), (arch, "paged", r.rid)
        assert np.array_equal(u_toks[r.rid], ref), (arch, "noshare", r.rid)

    ratio = p_alive / s_alive if s_alive else 0.0
    rec = {
        "slot": {"n_slots": mem_slots, "wall_s": s_wall,
                 "tok_s": n_tok / s_wall, "mean_alive": s_alive,
                 "pageable_kv_bytes": slot_bytes},
        "paged": {"n_slots": paged_slots, "page_size": page_size,
                  "n_pages": p.layout.n_pages, "wall_s": p_wall,
                  "tok_s": n_tok / p_wall, "mean_alive": p_alive,
                  "arena_kv_bytes": arena_bytes,
                  "prefix_hit_rate": p.stats["prefix_hits"]
                  / max(p.stats["prefix_lookups"], 1),
                  "scheduler": {k: p.stats[k] for k in
                                ("prefix_hits", "cow_copies", "preemptions",
                                 "swap_ins", "peak_pages_in_use")}},
        "useful_tokens": n_tok,
        "concurrency_ratio": ratio,
        "parity": True,
        "share_equals_noshare": True,
    }
    csv_row(f"table10/{arch}", p_wall * 1e6,
            f"mean_alive={p_alive:.2f}vs{s_alive:.2f};"
            f"concurrency_ratio={ratio:.2f}x;"
            f"prefix_hit_rate={rec['paged']['prefix_hit_rate']:.2f};"
            f"parity=True")
    return rec


def main(fast: bool = True):
    n_req = 32 if fast else 64
    mem_slots, paged_slots, page_size = 4, 12, 8
    max_new = 24 if fast else 48
    lens = [5, 12, 11, 16]
    buckets = (8, 16)
    out = {"shape": {"n_requests": n_req, "mem_slots": mem_slots,
                     "paged_slots": paged_slots, "page_size": page_size,
                     "max_new": max_new, "prompt_lens": lens,
                     "buckets": list(buckets)},
           "archs": {}}
    for arch in ARCHS:
        out["archs"][arch] = bench_arch(
            arch, n_req=n_req, mem_slots=mem_slots,
            paged_slots=paged_slots, page_size=page_size, max_new=max_new,
            lens=lens, buckets=buckets)
    ratios = [a["concurrency_ratio"] for a in out["archs"].values()]
    out["min_concurrency_ratio"] = min(ratios)
    assert out["min_concurrency_ratio"] >= 1.5, \
        f"paged concurrency under 1.5x at equal KV memory: {ratios}"
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table10_paged.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    print(json.dumps(main(fast=False), indent=1))
