"""Shared benchmark utilities: hardware profiles, timers, subprocess runner."""
from __future__ import annotations

import os
import subprocess
import sys
import time
from dataclasses import dataclass

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
ART = os.path.join(REPO, "benchmarks", "artifacts")


@dataclass(frozen=True)
class HwProfile:
    name: str
    flops: float          # peak FLOP/s per chip (bf16/fp16)
    hbm_bw: float         # bytes/s per chip
    link_bw: float        # bytes/s per chip interconnect (all-to-all usable)

    @property
    def desc(self):
        return (f"{self.name}: {self.flops/1e12:.0f} TFLOP/s, "
                f"{self.hbm_bw/1e9:.0f} GB/s HBM, "
                f"{self.link_bw/1e9:.1f} GB/s link")


# the TARGET for the roofline (per the spec): TPU v5e
TPU_V5E = HwProfile("tpu-v5e", 197e12, 819e9, 50e9)
# the paper's two clusters (approximate public specs)
V100_IB = HwProfile("v100-100Gb-IB", 112e12, 900e9, 12.5e9 / 8)   # IB shared per GPU
A100_IB = HwProfile("a100-1.6Tb-IB", 312e12, 2039e9, 200e9 / 8)


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        r = fn(*args)
    _block(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    _block(r)
    return (time.perf_counter() - t0) / iters


def _block(r):
    import jax
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, r)


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row


def decode_bleu(params, cfg, task, **kw) -> float:
    """Corpus BLEU of greedy decodes on a validation batch (MT task).

    The paper's actual Table-2/4 metric. Thin alias for the ONE
    corpus-BLEU-via-engine helper (launch/train.py::greedy_bleu) so
    train-time eval and the benchmarks can never drift apart."""
    from repro.launch.train import greedy_bleu
    return greedy_bleu(params, cfg, task, **kw)


def run_trainer(cfg, tc, *, batch, task=None, chunk=8,
                strategy="traced_cond", seq=32, n_langs=8, prefetch=True):
    """Train via the scan-fused Trainer (DESIGN.md §8) on the synthetic MT
    task — THE train-loop helper for quality/throughput benchmarks, so
    they measure the production loop rather than a hand-rolled one.

    Returns (state, task, history)."""
    from repro.data import MTTaskConfig, MultilingualMT
    from repro.training import Trainer
    if task is None:
        task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=n_langs,
                                           max_len=seq))
    trainer = Trainer(cfg, tc, task.train_batches(batch), chunk=chunk,
                      strategy=strategy, prefetch=prefetch, log=None)
    state, history = trainer.run()
    return state, task, history
