"""Paper Table 1 / Figure 3: all-to-all cost — baseline vs no-alltoall.

Two evidence sources (no IB cluster here):

(1) MEASURED on 8 simulated CPU devices: wall-clock MoE train step with
    routed (all-to-all present) vs dropped (local, no collective)
    executables — the host_cond pair. Also asserts the collective-byte
    difference from compiled HLO.

(2) ANALYTIC two-tier interconnect model (NVLink intra-node, shared IB
    inter-node) reproducing the paper's throughput-improvement-vs-#GPUs
    trend (Table 1: 11.8% @8 -> 93.8% @128). The model is calibrated at
    the paper's 8-GPU point only; the remaining points are predictions.
"""
from __future__ import annotations

import json

from benchmarks.common import csv_row, run_subprocess

PAPER_TABLE1 = {8: 11.8, 16: 46.5, 32: 79.1, 64: 88.5, 128: 93.8}


def measured_8dev():
    out = run_subprocess("""
import json, time
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, GatingDropoutConfig
from repro.core import init_moe_params, moe_sharded, ParallelContext
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ('data',))
ctx = ParallelContext(mesh=mesh)
cfg = ModelConfig(d_model=512, d_ff=1024, vocab=100, moe=MoEConfig(
    n_experts=8, top_k=1, d_ff_expert=1024,
    gating_dropout=GatingDropoutConfig(mode='gate_drop', rate=0.3,
                                       strategy='host_cond')))
p = init_moe_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (64, 128, 512), jnp.float32)
res = {}
from repro.analysis import parse_collectives
for dec, name in [(False, 'routed'), (True, 'dropped')]:
    f = jax.jit(lambda p, x: moe_sharded(p, x, cfg, ctx,
                rng=jax.random.PRNGKey(2), decision=dec)[0])
    c = f.lower(p, x).compile()
    hlo = c.as_text()
    colls = parse_collectives(hlo)
    y = f(p, x); y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        y = f(p, x)
    y.block_until_ready()
    res[name] = {'t': (time.perf_counter()-t0)/10,
                 'a2a_ops': hlo.count('all-to-all'),
                 'a2a_wire_bytes': colls.get('all-to-all', {}).get('wire_bytes', 0)}
print(json.dumps(res))
""")
    return json.loads(out.strip().splitlines()[-1])


def analytic_model(gpus_per_node: int = 8):
    """Two-tier interconnect model under WEAK scaling (per-GPU batch fixed,
    as in the paper: #experts == #GPUs).

    improvement(n) = T_a2a(n) / T_c
                   = a * local_frac(n) + b * remote_frac(n)

    local_frac  = intra-node share of each GPU's a2a traffic,
    remote_frac = (n - gpus_per_node)/n cross-node share.
    a = per-GPU a2a bytes / (NVLink bw * T_c); b = same over the shared IB.
    a, b are calibrated from the paper's two END points (8 and 128 GPUs);
    16/32/64 are PREDICTIONS of the model — the test of the paper's
    "communication cost is proportional to the number of involved
    machines" narrative.
    """
    def fracs(n):
        local = max(0, (min(gpus_per_node, n) - 1)) / n
        remote = max(0, n - gpus_per_node) / n
        return local, remote

    l8, _ = fracs(8)
    a = (PAPER_TABLE1[8] / 100.0) / l8
    l128, r128 = fracs(128)
    b = ((PAPER_TABLE1[128] / 100.0) - a * l128) / r128
    out = {}
    for n in PAPER_TABLE1:
        local, remote = fracs(n)
        out[n] = (a * local + b * remote) * 100.0
    # implied bandwidth ratio NVLink:IB per GPU
    out["ib_to_nvlink_time_ratio"] = b / a
    return out


def main(fast: bool = True):
    m = measured_8dev()
    t_r, t_d = m["routed"]["t"], m["dropped"]["t"]
    impr = (t_r - t_d) / t_d * 100.0
    csv_row("table1/measured_8dev_routed", t_r * 1e6,
            f"a2a_ops={m['routed']['a2a_ops']}")
    csv_row("table1/measured_8dev_dropped", t_d * 1e6,
            f"a2a_ops={m['dropped']['a2a_ops']};throughput_impr={impr:.1f}%")
    model = analytic_model()
    for n in PAPER_TABLE1:
        tag = " (calibration)" if n in (8, 128) else " (prediction)"
        csv_row(f"table1/analytic_n{n}", 0.0,
                f"model_impr={model[n]:.1f}%;paper={PAPER_TABLE1[n]:.1f}%"
                + tag)
    return {"measured": m, "analytic": model, "paper": PAPER_TABLE1}


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
