"""Paper Table 2 / Figure 5 (WMT-10): baseline vs Hash-Layer vs Gate-Drop vs
Gate-Expert-Drop — throughput, BLEU at convergence, steps/time-to-target.

Reduced Z-code-M3-base on the synthetic multilingual MT task (CPU). The
paper's qualitative claims under test:
  * Gate-Drop / Gate-Expert-Drop >= baseline final quality (regularization)
  * both reach the baseline's final quality in fewer steps / less time
  * throughput: Gate-Expert-Drop > Gate-Drop > Hash-Layer > baseline
  * Hash-Layer converges worse than gating-dropout variants

Quality is the paper's actual metric: corpus BLEU of greedy decodes
through the compiled engine (benchmarks/common.py::decode_bleu,
DESIGN.md §7); steps/time-to-target are BLEU-to-target columns. Token
accuracy is kept as a secondary signal. Training runs through the
scan-fused Trainer (DESIGN.md §8); eval cost (engine compile + decode)
is excluded from the training wall clock the table compares, and tok/s
counts ALL consumed tokens (encoder + decoder).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List

import jax.numpy as jnp

from benchmarks.common import csv_row, decode_bleu
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.data import MTTaskConfig, MultilingualMT
from repro.training import Trainer, make_eval_step

METHODS = {
    "baseline":         dict(router="softmax", mode="off", rate=0.0),
    "hash_layer":       dict(router="hash", mode="off", rate=0.0),
    "gate_drop":        dict(router="softmax", mode="gate_drop", rate=0.3),
    "gate_expert_drop": dict(router="softmax", mode="gate_expert_drop",
                             rate=0.2),
}


def make_cfg(method: Dict):
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(
        cfg.moe, router_type=method["router"],
        gating_dropout=GatingDropoutConfig(mode=method["mode"],
                                           rate=method["rate"]))
    return dataclasses.replace(cfg, moe=moe)


def run_method(name: str, method: Dict, *, steps: int, batch: int,
               seed: int, eval_every: int) -> Dict:
    cfg = make_cfg(method)
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 10), steps=steps,
                     seed=seed)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8))
    ev = make_eval_step(cfg)
    eval_cost: List[float] = []   # wall seconds per eval, in call order

    def eval_fn(state, i):
        te = time.time()
        vb = {k: jnp.asarray(v) for k, v in
              task.sample_batch(10_000, 64).items() if k != "lang"}
        em = ev(state["params"], vb)
        bleu = decode_bleu(state["params"], cfg, task, n=32, max_new=34)
        eval_cost.append(time.time() - te)
        return {"val_loss": float(em["loss"]), "val_acc": float(em["acc"]),
                "val_bleu": bleu}

    # the communication cost the dropped step avoids is free in the CPU
    # single process (wall-time gains are reported by table1); here we
    # count steps + eval metric. Eval points land on chunk ends, so each
    # record's boundary timestamp predates its own eval.
    trainer = Trainer(cfg, tc, task.train_batches(batch), chunk=8,
                      strategy="traced_cond", eval_every=eval_every,
                      eval_fn=eval_fn, log_every=0, log=None)
    _, history = trainer.run()
    evals: List[Dict] = []
    for idx, rec in enumerate(r for r in history if "val_bleu" in r):
        # training-only clock: boundary timestamp minus eval time accrued
        # at earlier boundaries (the seed-era t_eval bookkeeping)
        evals.append({"step": rec["step"], "val_loss": rec["val_loss"],
                      "val_acc": rec["val_acc"], "val_bleu": rec["val_bleu"],
                      "time_s": rec["time_s"] - sum(eval_cost[:idx])})
    dt = history[-1]["time_s"] - sum(eval_cost[:-1])
    b0 = task.train_batches(batch)(0)
    tokens = steps * (b0["tokens"].size + b0["enc_tokens"].size)
    return {"method": name, "evals": evals, "tok_s": tokens / dt,
            "final_acc": evals[-1]["val_acc"],
            "final_bleu": evals[-1]["val_bleu"],
            "final_loss": evals[-1]["val_loss"], "wall_s": dt}


def steps_to_target(evals: List[Dict], target_bleu: float):
    """First eval point whose corpus BLEU reaches the target — the paper's
    BLEU-to-target column."""
    for e in evals:
        if e["val_bleu"] >= target_bleu:
            return e["step"], e["time_s"]
    return None, None


def main(fast: bool = True):
    steps = 40 if fast else 400
    batch = 16 if fast else 32
    eval_every = max(steps // 6, 1)
    results = {}
    for name, method in METHODS.items():
        results[name] = run_method(name, method, steps=steps, batch=batch,
                                   seed=0, eval_every=eval_every)
    target = results["baseline"]["final_bleu"]
    for name, r in results.items():
        s2t, t2t = steps_to_target(r["evals"], target)
        r["steps_to_target"] = s2t
        r["time_to_target_s"] = t2t
        csv_row(f"table2/{name}",
                1e6 * r["wall_s"] / steps,
                f"final_bleu={r['final_bleu']:.2f};"
                f"final_acc={r['final_acc']:.3f};tok_s={r['tok_s']:.0f};"
                f"steps_to_bleu_target={s2t};"
                f"final_loss={r['final_loss']:.3f}")
    return results


if __name__ == "__main__":
    out = main(fast=False)
    print(json.dumps({k: {kk: vv for kk, vv in v.items() if kk != "evals"}
                      for k, v in out.items()}, indent=1))
