"""Paper Table 2 / Figure 5 (WMT-10): baseline vs Hash-Layer vs Gate-Drop vs
Gate-Expert-Drop — throughput, BLEU at convergence, steps/time-to-target.

Reduced Z-code-M3-base on the synthetic multilingual MT task (CPU). The
paper's qualitative claims under test:
  * Gate-Drop / Gate-Expert-Drop >= baseline final quality (regularization)
  * both reach the baseline's final quality in fewer steps / less time
  * throughput: Gate-Expert-Drop > Gate-Drop > Hash-Layer > baseline
  * Hash-Layer converges worse than gating-dropout variants

Quality is the paper's actual metric: corpus BLEU of greedy decodes
through the compiled engine (benchmarks/common.py::decode_bleu,
DESIGN.md §7); steps/time-to-target are BLEU-to-target columns. Token
accuracy is kept as a secondary signal.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, decode_bleu
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import drop_decision_host
from repro.data import MTTaskConfig, MultilingualMT
from repro.models import init_model
from repro.training import init_train_state, make_eval_step, make_train_step

METHODS = {
    "baseline":         dict(router="softmax", mode="off", rate=0.0),
    "hash_layer":       dict(router="hash", mode="off", rate=0.0),
    "gate_drop":        dict(router="softmax", mode="gate_drop", rate=0.3),
    "gate_expert_drop": dict(router="softmax", mode="gate_expert_drop",
                             rate=0.2),
}


def make_cfg(method: Dict):
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(
        cfg.moe, router_type=method["router"],
        gating_dropout=GatingDropoutConfig(mode=method["mode"],
                                           rate=method["rate"]))
    return dataclasses.replace(cfg, moe=moe)


def run_method(name: str, method: Dict, *, steps: int, batch: int,
               seed: int, eval_every: int) -> Dict:
    cfg = make_cfg(method)
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 10), steps=steps,
                     seed=seed)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8))
    params = init_model(jax.random.PRNGKey(seed), cfg)
    state = init_train_state(params, tc)
    step = make_train_step(cfg, tc)
    ev = make_eval_step(cfg)
    gd = cfg.moe.gating_dropout
    evals: List[Dict] = []
    tokens = 0
    t0 = time.time()
    t_eval = 0.0      # eval (incl. engine compile + decode) excluded from
                      # the training wall clock the table compares
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in task.sample_batch(i, batch).items()
             if k != "lang"}
        dec = drop_decision_host(gd, seed, i) if gd.enabled else False
        # simulate the communication cost the dropped step avoids: on the
        # CPU single process the a2a is free, so wall-time gains are
        # reported separately by table1; here we count steps + eval metric
        state, m = step(state, b, dec)
        tokens += int(b["tokens"].size)
        if i % eval_every == 0 or i == steps - 1:
            te = time.time()
            vb = {k: jnp.asarray(v) for k, v in
                  task.sample_batch(10_000, 64).items() if k != "lang"}
            em = ev(state["params"], vb)
            bleu = decode_bleu(state["params"], cfg, task, n=32, max_new=34)
            t_eval += time.time() - te
            evals.append({"step": i, "val_loss": float(em["loss"]),
                          "val_acc": float(em["acc"]), "val_bleu": bleu,
                          "time_s": time.time() - t0 - t_eval})
    dt = time.time() - t0 - t_eval
    return {"method": name, "evals": evals, "tok_s": tokens / dt,
            "final_acc": evals[-1]["val_acc"],
            "final_bleu": evals[-1]["val_bleu"],
            "final_loss": evals[-1]["val_loss"], "wall_s": dt}


def steps_to_target(evals: List[Dict], target_bleu: float):
    """First eval point whose corpus BLEU reaches the target — the paper's
    BLEU-to-target column."""
    for e in evals:
        if e["val_bleu"] >= target_bleu:
            return e["step"], e["time_s"]
    return None, None


def main(fast: bool = True):
    steps = 40 if fast else 400
    batch = 16 if fast else 32
    eval_every = max(steps // 6, 1)
    results = {}
    for name, method in METHODS.items():
        results[name] = run_method(name, method, steps=steps, batch=batch,
                                   seed=0, eval_every=eval_every)
    target = results["baseline"]["final_bleu"]
    for name, r in results.items():
        s2t, t2t = steps_to_target(r["evals"], target)
        r["steps_to_target"] = s2t
        r["time_to_target_s"] = t2t
        csv_row(f"table2/{name}",
                1e6 * r["wall_s"] / steps,
                f"final_bleu={r['final_bleu']:.2f};"
                f"final_acc={r['final_acc']:.3f};tok_s={r['tok_s']:.0f};"
                f"steps_to_bleu_target={s2t};"
                f"final_loss={r['final_loss']:.3f}")
    return results


if __name__ == "__main__":
    out = main(fast=False)
    print(json.dumps({k: {kk: vv for kk, vv in v.items() if kk != "evals"}
                      for k, v in out.items()}, indent=1))
