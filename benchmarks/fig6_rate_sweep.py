"""Paper Figure 6: effect of the dropout rate p on throughput and quality.

Sweeps p in {0, 0.1, ..., 0.5} for Gate-Expert-Drop (the paper's Fig-6
setting): quality from CPU training on the synthetic MT task, throughput
from the analytic step model (the a2a is free inside one CPU process).
Paper claims under test: throughput increases monotonically with p; the
quality delta peaks at a moderate p (0.2 in the paper) and goes NEGATIVE
at p = 0.5.
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp

from benchmarks.common import V100_IB, csv_row, run_trainer
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.obs import router_health
from repro.training import make_eval_step
from benchmarks.table3_throughput import step_terms


def quality(rate: float, *, steps: int, batch: int, seed: int = 0):
    """Final-accuracy probe per dropout rate, trained through the
    scan-fused Trainer. traced_cond: the decision stream is the same
    (seed, step) fold either way, and one executable per chunk length
    keeps the 6-rate sweep's compile cost sane.

    Returns (acc, router_health) — the health dict (mean entropy, load
    imbalance, realized drop rate from the in-graph MetricsFrame) shows
    WHY quality moves with p, not just that it does."""
    cfg = reduced(get_config("zcode-m3-base"))
    mode = "gate_expert_drop" if rate > 0 else "off"
    moe = dataclasses.replace(cfg.moe, gating_dropout=GatingDropoutConfig(
        mode=mode, rate=rate))
    cfg = dataclasses.replace(cfg, moe=moe)
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 10), steps=steps,
                     seed=seed)
    state, task, history = run_trainer(cfg, tc, batch=batch,
                                       strategy="traced_cond")
    ev = make_eval_step(cfg)
    vb = {k: jnp.asarray(v) for k, v in task.sample_batch(77_000, 64).items()
          if k != "lang"}
    return float(ev(state["params"], vb)["acc"]), router_health(history)


def model_throughput(rate: float) -> float:
    cfg = get_config("zcode-m3-big")
    t_c, t_a = step_terms(cfg, V100_IB, 64)
    # expert-drop: dropped steps skip both the a2a AND the routed-expert FLOPs
    t = t_c * (1.0 - rate * _expert_flop_share(cfg)) + t_a * (1.0 - rate)
    return 435_000 / t


def _expert_flop_share(cfg) -> float:
    """Fraction of active FLOPs in routed experts (skipped by expert-drop)."""
    act = cfg.n_active_params()
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.moe.is_moe_layer(i))
    n_moe += sum(1 for i in range(cfg.encdec.n_encoder_layers)
                 if cfg.moe.is_moe_layer(i))
    mlp_mult = 3 if cfg.gated_mlp else 2
    expert_params = n_moe * cfg.moe.top_k * mlp_mult * cfg.d_model * \
        cfg.moe.d_ff(cfg.d_ff)
    return expert_params / act


def main(fast: bool = True):
    steps = 35 if fast else 300
    batch = 16 if fast else 32
    rates = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]
    base_acc = None
    out = {}
    for p in rates:
        acc, health = quality(p, steps=steps, batch=batch)
        if base_acc is None:
            base_acc = acc
        tp = model_throughput(p)
        out[p] = {"acc": acc, "acc_delta": acc - base_acc,
                  "model_tok_s": tp}
        hnote = ""
        if health["records"]:
            out[p].update({f"router_{k}": v for k, v in health.items()
                           if k != "records"})
            hnote = (f";entropy={health['router_entropy']:.3f}"
                     f";imbalance={health['load_imbalance']:.2f}"
                     f";drop_rate={health['gate_drop_rate']:.2f}")
        csv_row(f"fig6/p{p:.1f}", 0.0,
                f"acc={acc:.3f};delta={acc-base_acc:+.3f};"
                f"model_tok_s={tp:.0f}" + hnote)
    return out


if __name__ == "__main__":
    print(json.dumps(main(fast=False), indent=1))
