"""Benchmark entry — one function per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # fast versions
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale (slow)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import traceback

from benchmarks.common import ART, csv_row


def bench_table1(fast):
    from benchmarks.table1_alltoall import main
    return main(fast)


def bench_table2(fast):
    from benchmarks.table2_wmt10 import main
    return main(fast)


def bench_table3(fast):
    from benchmarks.table3_throughput import main
    return main(fast)


def bench_table4(fast):
    from benchmarks.table4_multiling import main
    return main(fast)


def bench_fig6(fast):
    from benchmarks.fig6_rate_sweep import main
    return main(fast)


def bench_table5(fast):
    from benchmarks.table5_backends import main
    return main(fast)


def bench_table6(fast):
    from benchmarks.table6_decode import main
    return main(fast)


def bench_table7(fast):
    from benchmarks.table7_trainloop import main
    return main(fast)


def bench_table8(fast):
    from benchmarks.table8_serving import main
    return main(fast)


def bench_table9(fast):
    from benchmarks.table9_comm import main
    return main(fast)


def bench_table10(fast):
    from benchmarks.table10_paged import main
    return main(fast)


def bench_table11(fast):
    from benchmarks.table11_overlap import main
    return main(fast)


def bench_table12(fast):
    from benchmarks.table12_obs import main
    return main(fast)


def bench_roofline(fast):
    from benchmarks.roofline import analyze, bottleneck_note, load_joined
    recs = load_joined("pod256")
    if not recs:
        csv_row("roofline/skipped", 0.0, "no dryrun artifacts yet")
        return {}
    out = []
    for r in recs:
        a = analyze(r)
        out.append(a)
        step = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        csv_row(f"roofline/{a['arch']}/{a['shape']}", step * 1e6,
                f"dominant={a['dominant']};useful={a['useful_flops_ratio']:.2f};"
                f"roofline_frac={a['roofline_frac']:.3f}")
    return out


def bench_kernels(fast):
    """Micro-bench the Pallas kernels (interpret mode; CPU) vs jnp refs."""
    import jax
    import jax.numpy as jnp
    from benchmarks.common import timeit
    from repro.kernels import grouped_matmul, ref
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 256, 256))
    t_k = timeit(lambda: grouped_matmul(x, w, interpret=True), iters=3)
    t_r = timeit(lambda: jax.jit(ref.grouped_matmul_ref)(x, w), iters=3)
    csv_row("kernels/grouped_matmul_interpret", t_k * 1e6,
            f"jnp_ref_us={t_r*1e6:.1f} (interpret mode: correctness only)")
    return {"kernel_us": t_k * 1e6, "ref_us": t_r * 1e6}


BENCHES = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "table4": bench_table4,
    "fig6": bench_fig6,
    "table5": bench_table5,
    "table6": bench_table6,
    "table7": bench_table7,
    "table8": bench_table8,
    "table9": bench_table9,
    "table10": bench_table10,
    "table11": bench_table11,
    "table12": bench_table12,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    os.makedirs(ART, exist_ok=True)
    print("name,us_per_call,derived")
    results = {}
    failed = []
    for name in names:
        try:
            results[name] = BENCHES[name](not args.full)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            csv_row(f"{name}/FAILED", 0.0, f"{type(e).__name__}:{e}")
            traceback.print_exc()
    with open(os.path.join(ART, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
