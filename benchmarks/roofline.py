"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective wire bytes / (chips * link_bw)

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE flops /
bytes, so terms divide by per-chip peaks directly. Collective wire bytes
come from the HLO text parse (ring-algorithm per-device traffic).
Also reports MODEL_FLOPS = 6*N_active*D vs HLO_FLOPs (usefulness ratio).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from benchmarks.common import ART, TPU_V5E, HwProfile

DRY = os.path.join(ART, "dryrun")


# ---------------------------------------------------------------------------
# analytic attention-FLOP correction
# ---------------------------------------------------------------------------
# The flash attention used in train/prefill wraps its block loops in
# lax.scan / lax.map, which XLA cost analysis counts ONCE — the exact-cost
# artifacts therefore contain ~one (Cq x Ck) block per attention call
# (measured: 1/64 of the true total at L=8k). We add the analytic flops of
# what the runtime graph actually executes (masked FULL blocks: flash does
# not skip), and subtract nothing (the counted block is <2% error).

def attention_flops_correction(rec) -> float:
    """Per-device attention flops missing from the artifact."""
    from repro.configs import INPUT_SHAPES, get_config
    from repro.models.transformer import layer_plan
    if rec["kind"] == "decode":
        return 0.0           # decode attention is a direct einsum (counted)
    try:
        cfg = get_config(rec["arch"])
    except KeyError:
        return 0.0
    shape = INPUT_SHAPES[rec["shape"]]
    L, B = shape.seq_len, shape.global_batch
    flops = 0.0
    chunk_thresh = 2 * 1024   # flash path only when Lk > 2*chunk
    if L <= chunk_thresh:
        return 0.0
    for seg in layer_plan(cfg):
        for spec in seg.pattern:
            n = seg.repeats
            if spec.mixer in ("gqa", "hybrid"):
                hd2 = 2 * cfg.head_dim_
                pairs = float(L) * L   # masked full blocks
                flops += n * 2.0 * B * pairs * cfg.n_heads * hd2
            elif spec.mixer == "mla":
                m = cfg.mla
                dd = (m.qk_nope_head_dim + m.qk_rope_head_dim
                      + m.v_head_dim)
                flops += n * 2.0 * B * float(L) * L * cfg.n_heads * dd
    mult = 4.0 if rec["kind"] == "train" else 1.0   # bwd 2x + remat re-fwd
    return flops * mult / rec["n_devices"]


def analyze(rec: Dict, hw: HwProfile = TPU_V5E) -> Dict:
    n = rec["n_devices"]
    flops_dev = rec["flops"]                      # per-device (SPMD module)
    if rec.get("tag") in ("exact",) or str(rec.get("tag", "")).startswith("hc"):
        flops_dev += attention_flops_correction(rec)
    bytes_dev = rec["bytes_accessed"]
    wire = sum(c.get("wire_bytes", 0.0) for c in rec["collectives"].values())
    t_compute = flops_dev / hw.flops
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = wire / hw.link_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: 6*N_active*D tokens (training: x3 for fwd+bwd handled by
    # the 6; decode/prefill: 2*N_active*D)
    toks = rec["tokens_per_step"]
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["n_active_params"] * toks
    hlo_total = flops_dev * n
    useful = model_flops / hlo_total if hlo_total > 0 else 0.0
    step_time = max(terms.values())
    ideal = model_flops / (n * hw.flops)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["tag"] or
        ("pod512" if n == 512 else "pod256"),
        "n_devices": n,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "roofline_frac": ideal / step_time if step_time > 0 else 0.0,
        "hbm_gib": rec["memory"].get("argument_size_in_bytes", 0) / 2**30,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


def bottleneck_note(a: Dict) -> str:
    d = a["dominant"]
    if d == "collective":
        return ("skip/shrink the all-to-all (Gating Dropout reduces the "
                "expectation by p) or slice d over `model` before the a2a")
    if d == "memory":
        return ("raise arithmetic intensity: larger per-device batch, fuse "
                "elementwise chains, keep weights resident (bf16)")
    return ("near compute roof: cut redundant FLOPs (remat recompute, "
            "masked-causal waste) or overlap collectives with compute")


def load_records(mesh: str = "pod256", tag: str = "") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRY, f"*__{mesh}{tag}.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if (tag == "" and len(parts) != 3) or (tag and len(parts) != 4):
            continue
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def load_joined(mesh: str = "pod256") -> List[Dict]:
    """Exact-cost (unrolled) records, with memory figures taken from the
    scan-mode baseline (the production runtime uses scanned layers — its
    buffer assignment is the memory number that matters)."""
    exact = {(r["arch"], r["shape"]): r for r in load_records(mesh, "__exact")}
    scan = {(r["arch"], r["shape"]): r for r in load_records(mesh, "")}
    out = []
    for key, r in sorted(exact.items()):
        r = dict(r)
        if key in scan:
            r["memory"] = scan[key]["memory"]
        out.append(r)
    # combos not yet in the exact sweep fall back to scan records
    for key, r in sorted(scan.items()):
        if key not in exact:
            out.append(r)
    return out


def markdown_table(analyses: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOPs | roofline frac | args GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for a in analyses:
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3e} | "
            f"{a['t_memory_s']:.3e} | {a['t_collective_s']:.3e} | "
            f"**{a['dominant']}** | {a['useful_flops_ratio']*100:.0f}% | "
            f"{a['roofline_frac']*100:.1f}% | {a['hbm_gib']:.1f} |")
    return hdr + "\n".join(rows)


def gate() -> int:
    """CI acceptance gate for the fused MoE megakernel (DESIGN.md §11).

    Reads the committed table5 artifact and fails (nonzero) unless the
    ``pallas_fused`` backend is strictly faster than the unfused pallas
    pipeline AND launches at most half as many pallas kernels per layer.
    """
    path = os.path.join(ART, "table5_backends.json")
    if not os.path.exists(path):
        print(f"GATE FAIL: missing artifact {path} "
              "(run benchmarks.table5_backends first)")
        return 1
    with open(path) as f:
        res = json.load(f)
    try:
        fused = res["backends"]["pallas_fused"]
        pallas = res["backends"]["pallas"]
    except KeyError as e:
        print(f"GATE FAIL: artifact missing backend entry {e}")
        return 1
    ok = True
    if not fused["t_layer_us"] < pallas["t_layer_us"]:
        print(f"GATE FAIL: fused {fused['t_layer_us']:.1f} us/layer not "
              f"faster than pallas {pallas['t_layer_us']:.1f} us/layer")
        ok = False
    if not fused["pallas_launches"] * 2 <= pallas["pallas_launches"]:
        print(f"GATE FAIL: fused launches {fused['pallas_launches']} not "
              f"<= half of pallas {pallas['pallas_launches']}")
        ok = False
    if ok:
        speedup = pallas["t_layer_us"] / fused["t_layer_us"]
        print(f"GATE OK: fused {fused['t_layer_us']:.1f} us/layer vs "
              f"pallas {pallas['t_layer_us']:.1f} us/layer "
              f"({speedup:.2f}x), launches "
              f"{fused['pallas_launches']} vs {pallas['pallas_launches']}")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod256")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="megakernel acceptance gate over the table5 "
                         "artifact (exit 1 on regression)")
    args = ap.parse_args()
    if args.gate:
        raise SystemExit(gate())
    if args.tag is None:
        recs = load_joined(args.mesh)
    else:
        recs = load_records(args.mesh, f"__{args.tag}" if args.tag else "")
    analyses = [analyze(r) for r in recs]
    if args.markdown:
        print(markdown_table(analyses))
        return
    print("arch,shape,mesh,t_compute,t_memory,t_collective,dominant,"
          "useful_ratio,roofline_frac,note")
    for a in analyses:
        print(f"{a['arch']},{a['shape']},{a['mesh']},{a['t_compute_s']:.4e},"
              f"{a['t_memory_s']:.4e},{a['t_collective_s']:.4e},"
              f"{a['dominant']},{a['useful_flops_ratio']:.3f},"
              f"{a['roofline_frac']:.3f},\"{bottleneck_note(a)}\"")


if __name__ == "__main__":
    main()
