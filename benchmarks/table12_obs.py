"""Table 12 (beyond paper): observability overhead — tracer + MetricsFrame.

The acceptance claim of the DESIGN.md §15 observability layer: turning
EVERYTHING on (span tracer enabled, in-graph MetricsFrame on, scheduler
registry live) costs < 2% end-to-end against the fully-dark
configuration, and the MetricsFrame changes no computed number — the
per-step losses are BITWISE identical with the frame on and off.

Two measurements, both warmed and interleaved (min-of-reps, so a single
scheduler hiccup on one variant cannot fake an overhead):

  train — the table7 train config (reduced zcode-m3-base, gate_drop 0.3,
      traced_cond). Timed at the Trainer._dispatch level: one scan-fused
      chunk per rep, baseline = (tracer disabled, metrics_frame=False) vs
      instrumented = (tracer enabled, metrics_frame=True).
  serve — a table8-style backlogged mixed trace through
      ContinuousScheduler, baseline = disabled tracer vs instrumented =
      enabled tracer + live registry. Greedy per-request token parity
      across the two runs is asserted.

Writes benchmarks/artifacts/table12_obs.json (schema:
benchmarks/README.md). Gate: overhead < 2% on both sides, bitwise loss
equality, serve token parity.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ART, csv_row
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.data import MTTaskConfig, MultilingualMT, stack_batches
from repro.models import init_model
from repro.obs import Tracer, MetricsRegistry
from repro.serve import ContinuousScheduler, GenerateConfig, Request
from repro.training import Trainer

# table7's shape: small per-step device work ON PURPOSE — per-chunk host
# overhead (what the tracer could inflate) is a fixed cost, and it must
# stay invisible even when the device step is only milliseconds
BATCH, SEQ, CHUNK = 2, 10, 16
OVERHEAD_BAR = 0.02


def _train_cfg():
    cfg = reduced(get_config("zcode-m3-base"), d_model=64, d_ff=128,
                  vocab=256, n_heads=2, n_kv_heads=2, head_dim=32)
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, d_ff_expert=128,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3)))


def _trainer(cfg, *, frame: bool, traced: bool):
    tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=CHUNK, seed=0,
                     metrics_frame=frame)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8,
                                       max_len=SEQ, src_len=(4, 8)))
    tr = Trainer(cfg, tc, task.train_batches(BATCH), chunk=CHUNK,
                 strategy="traced_cond", log=None,
                 tracer=Tracer(enabled=traced))
    stacked = stack_batches(tr.batch_fn, 0, CHUNK)
    tr._dispatch((0, CHUNK), stacked)          # compile off the clock
    return tr, stacked


def bench_train(reps: int = 5):
    """min-of-reps chunk dispatch time, baseline vs fully instrumented."""
    cfg = _train_cfg()
    base, b_batch = _trainer(cfg, frame=False, traced=False)
    inst, i_batch = _trainer(cfg, frame=True, traced=True)
    t_off, t_on = [], []
    for _ in range(reps):                      # interleaved pairs
        t0 = time.perf_counter()
        base._dispatch((0, CHUNK), b_batch)
        t_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        inst._dispatch((0, CHUNK), i_batch)
        t_on.append(time.perf_counter() - t0)
    return min(t_off), min(t_on)


def check_train_bitwise():
    """Frame on vs off from identical init: the telemetry switch must not
    move one bit of the computed loss/acc stream."""
    cfg = _train_cfg()
    ms = {}
    for frame in (False, True):
        tr, stacked = _trainer(cfg, frame=frame, traced=False)
        ms[frame] = tr._dispatch((CHUNK, 2 * CHUNK),
                                 stack_batches(tr.batch_fn, CHUNK,
                                               2 * CHUNK))
    loss_eq = np.array_equal(ms[False]["loss"], ms[True]["loss"])
    acc_eq = np.array_equal(ms[False]["acc"], ms[True]["acc"])
    frame_keys = set(ms[True]) - set(ms[False])
    return loss_eq and acc_eq, sorted(frame_keys)


def _serve_cfg():
    cfg = reduced(get_config("yi-6b"), d_model=128, n_layers=2, d_ff=256,
                  head_dim=64)
    if cfg.moe is not None:                    # placement-invariant MoE
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.n_experts)))
    return cfg


def _trace(cfg, n: int = 8):
    rs = np.random.RandomState(7)
    reqs = []
    for i in range(n):
        plen = (4, 6, 8)[i % 3]
        toks = rs.randint(3, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks,
                            max_new=int(rs.randint(4, 17)), arrival=0.0))
    return reqs


def _serve_once(params, cfg, gen, reqs, *, traced: bool):
    sched = ContinuousScheduler(params, cfg, gen, n_slots=4,
                                prefill_buckets=(8,),
                                registry=MetricsRegistry(),
                                tracer=Tracer(enabled=traced))
    t0 = time.perf_counter()
    results = sched.run(reqs)
    return time.perf_counter() - t0, {r.rid: r.tokens for r in results}


def bench_serve(reps: int = 8):
    cfg = _serve_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new=16, eos_id=-1)
    reqs = _trace(cfg)
    _serve_once(params, cfg, gen, reqs, traced=False)   # compile off-clock
    t_off, t_on, parity = [], [], True
    for _ in range(reps):                               # interleaved pairs
        w0, toks0 = _serve_once(params, cfg, gen, reqs, traced=False)
        w1, toks1 = _serve_once(params, cfg, gen, reqs, traced=True)
        t_off.append(w0)
        t_on.append(w1)
        parity = parity and all(np.array_equal(toks0[r], toks1[r])
                                for r in toks0)
    return min(t_off), min(t_on), parity


def main(fast: bool = True):
    reps = 5 if fast else 9
    tr_off, tr_on = bench_train(reps)
    train_over = tr_on / tr_off - 1.0
    bitwise, frame_keys = check_train_bitwise()
    # scheduler wall clocks are noisy (±10% per run on a shared CPU);
    # 8 interleaved pairs lets min-of-reps converge on the real floor
    sv_off, sv_on, parity = bench_serve(8 if fast else 12)
    serve_over = sv_on / sv_off - 1.0

    csv_row("table12/train_chunk_off", tr_off * 1e6,
            f"instrumented_us={tr_on*1e6:.0f};overhead={train_over:+.3%}")
    csv_row("table12/serve_trace_off", sv_off * 1e6,
            f"instrumented_us={sv_on*1e6:.0f};overhead={serve_over:+.3%}")

    assert bitwise, "MetricsFrame changed the computed loss/acc stream"
    assert parity, "tracer changed served tokens"
    # the acceptance bar this table exists to hold: full observability
    # under 2% end-to-end (min-of-reps; negative = measurement noise)
    assert train_over < OVERHEAD_BAR, \
        f"train observability overhead {train_over:.3%} >= 2%"
    assert serve_over < OVERHEAD_BAR, \
        f"serve observability overhead {serve_over:.3%} >= 2%"

    out = {
        "config": {"train": "zcode-m3-base(reduced, d_model=64) "
                            "gate_drop@0.3 traced_cond",
                   "serve": "yi-6b(reduced, d_model=128) greedy backlog",
                   "batch": BATCH, "seq": SEQ, "chunk": CHUNK,
                   "overhead_bar": OVERHEAD_BAR},
        "train": {"baseline_s": tr_off, "instrumented_s": tr_on,
                  "overhead_frac": train_over,
                  "bitwise_loss_equal": bool(bitwise),
                  "frame_only_keys": frame_keys},
        "serve": {"baseline_s": sv_off, "instrumented_s": sv_on,
                  "overhead_frac": serve_over,
                  "token_parity": bool(parity)},
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table12_obs.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="CI alias: run the fast benchmark (the asserts "
                         "ARE the gate)")
    args = ap.parse_args()
    res = main(fast=not args.full)
    print(json.dumps(res, indent=1))
