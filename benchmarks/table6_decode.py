"""Table 6 (beyond paper): compiled decode loop vs legacy per-token loop.

Measures end-to-end generation (prefill + max_new greedy tokens) two ways
on 2-3 reduced archs covering the cache families:

  * compiled -- the engine (repro.serve, DESIGN.md §7): prefill + the
    whole ``lax.while_loop`` in ONE jitted executable.
  * legacy   -- the pre-engine shape: jitted prefill, then a host-side
    Python loop dispatching one jitted ``decode_step`` per token (what
    launch/serve.py, examples/serve_decode.py and train.py::greedy_bleu
    each hand-rolled before PR 2).

Both paths emit identical greedy tokens (asserted); the benchmark records
throughput for each and the speedup into
``benchmarks/artifacts/table6_decode.json`` (schema: benchmarks/README.md).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ART, csv_row
from repro.configs import get_config, reduced
from repro.models import decode_step, init_model, prefill
from repro.serve import GenerateConfig, make_generate_fn

ARCHS = ["yi-6b", "zcode-m3-base", "mamba2-1.3b"]


def _batch(cfg, key, b, prompt_len):
    batch = {"tokens": jax.random.randint(key, (b, prompt_len), 3, cfg.vocab)}
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (b, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(key, (b, 32), 3,
                                                     cfg.vocab)
    return batch


def make_legacy_fns(cfg, prompt_len: int, max_new: int):
    """Jitted prefill + per-token decode_step, built ONCE so the timed
    loop measures dispatch (not retracing)."""
    pre = jax.jit(lambda p, b: prefill(p, b, cfg,
                                       max_seq=prompt_len + max_new))
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    return pre, step


def legacy_generate(params, batch, pre, step, max_new: int):
    """The old per-token-Python-dispatch loop (correctly indexed)."""
    prompt_len = batch["tokens"].shape[1]
    logits, caches = pre(params, batch)
    cur = logits.argmax(-1).astype(jnp.int32)
    outs = [np.asarray(cur)[:, 0]]
    for i in range(max_new - 1):
        logits, caches = step(params, caches, cur, prompt_len + i)
        cur = logits.argmax(-1).astype(jnp.int32)
        outs.append(np.asarray(cur)[:, 0])
    return np.stack(outs, 1)


def _time(fn, iters: int):
    jax.block_until_ready(fn())            # warmup (compile) fully retired
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def bench_arch(arch: str, *, batch: int, prompt_len: int, max_new: int,
               iters: int) -> Dict:
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    b = _batch(cfg, key, batch, prompt_len)

    fn = make_generate_fn(cfg, GenerateConfig(max_new=max_new, eos_id=-1))
    t_comp, res = _time(lambda: fn(params, b), iters)
    pre, step = make_legacy_fns(cfg, prompt_len, max_new)
    t_leg, leg = _time(lambda: legacy_generate(params, b, pre, step,
                                               max_new), iters)
    tokens_equal = bool(
        (np.asarray(res.tokens) == np.asarray(leg)).all())
    n_tok = batch * max_new
    rec = {
        "compiled": {"wall_s": t_comp, "tok_s": n_tok / t_comp},
        "legacy": {"wall_s": t_leg, "tok_s": n_tok / t_leg},
        "speedup": t_leg / t_comp,
        "tokens_equal": tokens_equal,
    }
    csv_row(f"table6/{arch}", t_comp * 1e6,
            f"compiled_tok_s={rec['compiled']['tok_s']:.0f};"
            f"legacy_tok_s={rec['legacy']['tok_s']:.0f};"
            f"speedup={rec['speedup']:.2f}x;tokens_equal={tokens_equal}")
    assert tokens_equal, f"{arch}: compiled and legacy loops diverged"
    return rec


def main(fast: bool = True):
    batch, prompt_len = (4, 16) if fast else (8, 64)
    max_new = 16 if fast else 64
    iters = 2 if fast else 5
    out = {"shape": {"batch": batch, "prompt_len": prompt_len,
                     "max_new": max_new, "iters": iters},
           "archs": {}}
    for arch in ARCHS:
        out["archs"][arch] = bench_arch(arch, batch=batch,
                                        prompt_len=prompt_len,
                                        max_new=max_new, iters=iters)
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table6_decode.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    print(json.dumps(main(fast=False), indent=1))
