"""Paper Table 4 (Web-50): per-direction quality incl. LOW-RESOURCE split.

Trains baseline vs Gate-Drop on the synthetic multilingual task whose last
quarter of languages are low-resource (5% sampling weight), then evaluates
per-language corpus BLEU — the paper's actual metric, greedy-decoded
through the compiled engine (DESIGN.md §7) — plus token accuracy. Paper
claim under test: Gating Dropout's regularization helps MOST on
low-resource languages.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, decode_bleu, run_trainer
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.data import MTTaskConfig, MultilingualMT
from repro.training import make_eval_step


def train_and_eval(mode: str, rate: float, *, steps: int, batch: int,
                   seed: int = 0) -> Dict:
    cfg = reduced(get_config("zcode-m3-base"))
    moe = dataclasses.replace(cfg.moe, gating_dropout=GatingDropoutConfig(
        mode=mode, rate=rate))
    cfg = dataclasses.replace(cfg, moe=moe)
    tcfg = MTTaskConfig(vocab=cfg.vocab, n_langs=8, low_resource_frac=0.25,
                        low_resource_weight=0.05)
    task = MultilingualMT(tcfg)
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 10), steps=steps,
                     seed=seed)
    # train through the scan-fused Trainer (DESIGN.md §8); the decision
    # stream is the same (seed, step) fold the per-step loop drew
    state, _, _ = run_trainer(cfg, tc, batch=batch, task=task,
                              strategy="traced_cond")
    ev = make_eval_step(cfg)
    per_lang = {}
    per_lang_bleu = {}
    for lang in range(tcfg.n_langs):
        vb = task.sample_batch(50_000 + lang, 32, lang=lang)
        vb = {k: jnp.asarray(v) for k, v in vb.items() if k != "lang"}
        per_lang[lang] = float(ev(state["params"], vb)["acc"])
        per_lang_bleu[lang] = decode_bleu(state["params"], cfg, task,
                                          n=16, max_new=34,
                                          seed=50_000 + lang, lang=lang)
    low = [per_lang[l] for l in task.low_langs]
    high = [per_lang[l] for l in range(tcfg.n_langs)
            if l not in task.low_langs]
    bleu_low = [per_lang_bleu[l] for l in task.low_langs]
    bleu_high = [per_lang_bleu[l] for l in range(tcfg.n_langs)
                 if l not in task.low_langs]
    return {"per_lang": per_lang, "per_lang_bleu": per_lang_bleu,
            "avg": float(np.mean(list(per_lang.values()))),
            "low": float(np.mean(low)), "high": float(np.mean(high)),
            "bleu_avg": float(np.mean(list(per_lang_bleu.values()))),
            "bleu_low": float(np.mean(bleu_low)),
            "bleu_high": float(np.mean(bleu_high))}


def main(fast: bool = True):
    steps = 40 if fast else 400
    batch = 16 if fast else 32
    res = {
        "baseline": train_and_eval("off", 0.0, steps=steps, batch=batch),
        "gate_drop": train_and_eval("gate_drop", 0.3, steps=steps,
                                    batch=batch),
    }
    for name, r in res.items():
        csv_row(f"table4/{name}", 0.0,
                f"bleu_avg={r['bleu_avg']:.2f};"
                f"bleu_low={r['bleu_low']:.2f};"
                f"bleu_high={r['bleu_high']:.2f};"
                f"acc_avg={r['avg']:.3f};acc_low={r['low']:.3f};"
                f"acc_high={r['high']:.3f}")
    d_low = res["gate_drop"]["bleu_low"] - res["baseline"]["bleu_low"]
    d_all = res["gate_drop"]["bleu_avg"] - res["baseline"]["bleu_avg"]
    csv_row("table4/delta", 0.0,
            f"gatedrop_minus_baseline_bleu_avg={d_all:+.2f};"
            f"bleu_low={d_low:+.2f}")
    return res


if __name__ == "__main__":
    print(json.dumps(main(fast=False), indent=1))
