"""Table 8 (beyond paper): continuous vs static batching on a mixed trace.

The serving claim of the continuous-batching refactor (DESIGN.md §9):
on a trace of requests with mixed prompt lengths and mixed per-request
token budgets, the slot-pool scheduler sustains >= 1.3x the useful-token
throughput of static batching, because a static batch runs until its
SLOWEST member finishes while the scheduler refills retired slots
mid-flight.

Measured per arch (reduced configs; MoE archs get non-binding eval
capacity so expert truncation cannot couple requests):

  * static     -- the pre-refactor shape: requests grouped FIFO into
                  same-length batches of `slots`, each batch run through
                  the ONE-SHOT engine for the full gen.max_new steps (the
                  one-shot loop cannot see per-request budgets — that is
                  exactly what the refactor adds).
  * continuous -- `repro.serve.ContinuousScheduler` over the same trace.
  * continuous+local -- MoE archs only: same, with `local_routing=True`
                  (Gate-Drop local path at decode; token parity with the
                  routed column asserted at ep=1, where the local group
                  is all experts).

Per-request TOKEN PARITY of the continuous path against one-shot
``generate`` (B=1, pool cache length) is asserted for every request;
both paths are fully warmed before timing. Results land in
``benchmarks/artifacts/table8_serving.json`` (schema: benchmarks/
README.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import ART, csv_row
from repro.configs import get_config, reduced
from repro.models import init_model
from repro.serve import (ContinuousScheduler, GenerateConfig, Request,
                         generate, static_batch_serve)

ARCHS = ["yi-6b", "zcode-m3-base"]
KEY = jax.random.PRNGKey(0)


def _ample(cfg):
    """Non-binding eval expert capacity: required for request-placement-
    invariant MoE decoding (DESIGN.md §9)."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, eval_capacity_factor=float(cfg.moe.n_experts)))


def _bench_cfg(arch: str):
    """Narrowed reduced config (table7 precedent): wide enough that the
    device decode step dominates per-tick host dispatch — the regime an
    accelerator is always in — so the measured gap is batching policy,
    not Python overhead."""
    return _ample(reduced(get_config(arch), d_model=512, n_layers=4,
                          d_ff=1024, head_dim=128))


def _extras(cfg, key):
    out = {}
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            out["frames"] = np.asarray(jax.random.normal(
                key, (cfg.encdec.encoder_seq, cfg.d_model)), np.float32)
        else:
            out["enc_tokens"] = np.asarray(jax.random.randint(
                key, (32,), 3, cfg.vocab), np.int32)
    return out


def make_trace(cfg, key, n: int, lens: List[int], max_new: int
               ) -> List[Request]:
    """Backlogged trace (all arrive at t=0): prompt lengths cycle through
    ``lens``; token budgets are LONG-TAILED (75% short 2-8, 25% near
    max_new) — the real serving distribution where one long response pins
    an entire static batch to its finish line."""
    rs = np.random.RandomState(7)
    reqs = []
    for i in range(n):
        plen = lens[i % len(lens)]
        if rs.rand() < 0.75:
            budget = int(rs.randint(2, 9))
        else:
            budget = int(rs.randint(max(2, max_new - 8), max_new + 1))
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 3, cfg.vocab), np.int32)
        reqs.append(Request(
            rid=i, tokens=toks, max_new=budget, arrival=0.0,
            extras=_extras(cfg, jax.random.fold_in(key, 1000 + i))))
    return reqs


def _run_continuous(params, cfg, gen, reqs, slots, buckets):
    sched = ContinuousScheduler(params, cfg, gen, n_slots=slots,
                                prefill_buckets=buckets)
    t0 = time.perf_counter()
    results = sched.run(reqs)
    wall = time.perf_counter() - t0
    toks = {r.rid: r.tokens for r in results}
    n_tok = int(sum(r.length for r in results))
    return toks, n_tok, wall, sched


def _best_of(fn, iters: int):
    """(result, min wall): noise-robust timing — each iter replays the
    whole warmed trace, the minimum wall is the least-interference run."""
    best = None
    out = None
    for _ in range(iters):
        r, wall = fn()
        if best is None or wall < best:
            best, out = wall, r
    return out, best


def bench_arch(arch: str, *, n_req: int, slots: int, max_new: int,
               lens: List[int], buckets, iters: int = 5) -> Dict:
    cfg = _bench_cfg(arch)
    params = init_model(KEY, cfg)
    gen = GenerateConfig(max_new=max_new, eos_id=-1)
    reqs = make_trace(cfg, jax.random.fold_in(KEY, 2), n_req, lens, max_new)

    # warmup (compiles) then measure best-of-iters full-trace replays
    _run_continuous(params, cfg, gen, reqs, slots, buckets)
    (c_toks, c_n, sched), c_wall = _best_of(
        lambda: ((lambda t, n, w, s: ((t, n, s), w))(
            *_run_continuous(params, cfg, gen, reqs, slots, buckets))),
        iters)
    static_batch_serve(params, cfg, gen, reqs, batch_size=slots,
                       max_seq=sched.max_seq)
    s_toks, s_wall = _best_of(
        lambda: static_batch_serve(params, cfg, gen, reqs,
                                   batch_size=slots,
                                   max_seq=sched.max_seq), iters)

    # parity: every request == one-shot generate (B=1, pool cache length)
    gref = dataclasses.replace(gen, max_seq=sched.max_seq)
    parity = True
    for r in reqs:
        batch = {"tokens": r.tokens[None]}
        for k, v in r.extras.items():
            batch[k] = v[None]
        one = generate(params, batch, cfg, gref)
        n = min(int(one.lengths[0]), r.max_new)
        ref = np.asarray(one.tokens)[0, :n]
        parity &= bool(np.array_equal(c_toks[r.rid], ref))
        parity &= bool(np.array_equal(s_toks[r.rid], ref))
    assert parity, f"{arch}: continuous/static diverged from one-shot"

    useful = c_n                   # same trace -> same useful tokens
    rec = {
        "continuous": {"wall_s": c_wall, "tok_s": useful / c_wall,
                       "scheduler": dict(sched.stats)},
        "static": {"wall_s": s_wall, "tok_s": useful / s_wall},
        "useful_tokens": useful,
        "speedup": s_wall / c_wall,
        "parity": parity,
    }

    if cfg.moe is not None:
        gloc = dataclasses.replace(gen, local_routing=True)
        _run_continuous(params, cfg, gloc, reqs, slots, buckets)
        l_toks, _, l_wall, _ = _run_continuous(params, cfg, gloc, reqs,
                                               slots, buckets)
        # ep=1: the local group is all experts -> identical tokens
        local_parity = all(np.array_equal(l_toks[r.rid], c_toks[r.rid])
                           for r in reqs)
        rec["continuous_local_routing"] = {
            "wall_s": l_wall, "tok_s": useful / l_wall,
            "tokens_equal_routed": bool(local_parity),
        }

    csv_row(f"table8/{arch}", c_wall * 1e6,
            f"continuous_tok_s={rec['continuous']['tok_s']:.0f};"
            f"static_tok_s={rec['static']['tok_s']:.0f};"
            f"speedup={rec['speedup']:.2f}x;parity={parity}")
    return rec


def main(fast: bool = True):
    n_req, slots = (32, 8) if fast else (64, 8)
    max_new = 24 if fast else 48
    lens = [5, 8, 11, 16]
    buckets = (8, 16)
    out = {"shape": {"n_requests": n_req, "slots": slots,
                     "max_new": max_new, "prompt_lens": lens,
                     "buckets": list(buckets)},
           "archs": {}}
    for arch in ARCHS:
        out["archs"][arch] = bench_arch(arch, n_req=n_req, slots=slots,
                                        max_new=max_new, lens=lens,
                                        buckets=buckets)
    speedups = [a["speedup"] for a in out["archs"].values()]
    out["min_speedup"] = min(speedups)
    assert out["min_speedup"] >= 1.3, \
        f"continuous batching under 1.3x: {speedups}"
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table8_serving.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    print(json.dumps(main(fast=False), indent=1))
