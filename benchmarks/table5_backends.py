"""Table 5 (beyond paper): MoE execution-backend latency comparison.

Times one MoE layer forward — and the dispatch / expert-FFN / combine
phases of the pallas pipeline — for each registered backend at the
zcode_m3 expert shape (reduced widths in fast mode so the CPU container
finishes). On this container every backend runs on CPU (pallas in
interpret mode), so the numbers rank *relative* per-phase cost and prove
the pipeline works end-to-end; on a real TPU pod the same script compares
compiled-kernel against XLA-collective execution.

The ``pallas_fused`` megakernel backend (DESIGN.md §11) replaces the
dispatch -> grouped-FFN -> combine pipeline with ONE pallas_call; the
per-backend ``pallas_launches`` count (pallas_call occurrences in the
jaxpr of one layer forward) is the structural evidence, and
``benchmarks.roofline --gate`` enforces both it and the latency win.

Output: benchmarks/artifacts/table5_backends.json

  {"shape": {...},
   "backends": {"<name>": {"t_layer_us": float, "pallas_launches": int}},
   "pallas_phases": {"routing_tables_us": ..., "dispatch_us": ...,
                     "ffn_us": ..., "combine_us": ...,
                     "fused_moe_us": ...}}
"""
from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import ART, csv_row, timeit


def main(fast: bool = True):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.core import get_backend, init_moe_params
    from repro.core import router as R
    from repro.kernels import ops as K

    cfg = get_config("zcode-m3-base")
    if fast:
        cfg = reduced(cfg)
        B, L = 8, 64
    else:
        B, L = 8, 512
    moe = cfg.moe
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))

    res = {"shape": {"arch": cfg.arch_id, "batch": B, "seq": L,
                     "d_model": cfg.d_model, "n_experts": moe.n_experts,
                     "top_k": moe.top_k, "d_ff_expert": moe.d_ff(cfg.d_ff)},
           "backends": {}, "pallas_phases": {}}

    for name in ("oracle", "pallas", "pallas_fused", "sharded"):
        fn = get_backend(name)
        step = jax.jit(lambda p_, x_: fn(p_, x_, cfg, None, rng=None,
                                         decision=False, is_training=True,
                                         token_ids=None)[0])
        t = timeit(step, p, x, warmup=2, iters=5)
        # structural launch count: pallas_call occurrences in the layer
        # jaxpr (fused = 1, pipeline = dispatch + 2x gmm + combine)
        launches = str(jax.make_jaxpr(step)(p, x)).count("pallas_call")
        res["backends"][name] = {"t_layer_us": t * 1e6,
                                 "pallas_launches": launches}
        csv_row(f"table5/{name}/layer_fwd", t * 1e6,
                f"E={moe.n_experts};k={moe.top_k};tokens={B*L};"
                f"launches={launches}")

    # pallas phase breakdown: routing tables / dispatch / grouped FFN / combine
    xf = x.reshape(-1, cfg.d_model)
    T, E = xf.shape[0], moe.n_experts
    cap = min(R.capacity(T, E, moe.top_k, moe.capacity_factor), T)
    wr = p["router"]["w"]
    rr = R.route(wr, xf, moe, is_training=False)
    info = R.dispatch_info(rr, E, cap)
    tables = K.routing_tables(info, E, cap)
    buf = K.dispatch(xf, tables.slot_token, tables.slot_valid)
    ebuf = buf.reshape(E, cap, -1)
    ffn = jax.jit(lambda b: K.expert_ffn_op(
        b, p["experts"]["w_in"], p["experts"].get("w_gate"),
        p["experts"]["w_out"], cfg.act))
    out = ffn(ebuf)
    phases = {
        "routing_tables_us": timeit(
            jax.jit(lambda i: K.routing_tables(i, E, cap)), info) * 1e6,
        "dispatch_us": timeit(
            lambda: K.dispatch(xf, tables.slot_token, tables.slot_valid)) * 1e6,
        "ffn_us": timeit(ffn, ebuf) * 1e6,
        "combine_us": timeit(
            lambda: K.combine(out.reshape(E * cap, -1), tables.token_slot,
                              info.topk_w, info.keep)) * 1e6,
        # the megakernel does all three phases above in one launch
        "fused_moe_us": timeit(
            lambda: K.fused_moe_op(xf, info, p["experts"]["w_in"],
                                   p["experts"].get("w_gate"),
                                   p["experts"]["w_out"], E, cap, cfg.act,
                                   tables=tables)) * 1e6,
    }
    res["pallas_phases"] = phases
    for k, v in phases.items():
        csv_row(f"table5/pallas/{k[:-3]}", v, f"cap={cap};slots={E*cap}")

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table5_backends.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    import sys
    main(fast="--full" not in sys.argv)
