"""Paper Table 3 (Web-50): throughput of baseline / Gate-Drop /
Gate-Expert-Drop on two clusters (V100 + 100Gb IB vs A100 + 1.6Tb IB).

Analytic roofline model of the zcode-m3-big MoE training step per method
per hardware profile, plus a MEASURED column: real steps/s of the
scan-fused Trainer (DESIGN.md §8) on the reduced CPU config per method.
The paper's qualitative claim under test: the RELATIVE improvement from
Gating Dropout is larger on the slower (more communication-bound)
cluster. (The measured CPU column only reflects Gate-Expert-Drop's FLOP
savings — in-process the all-to-all is free, so gate_drop measures ~1x.)
"""
from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.common import (A100_IB, TPU_V5E, V100_IB, HwProfile, csv_row,
                               run_trainer)
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import (expected_alltoall_fraction,
                                       expected_expert_flop_fraction)

SEQ = 1024
GLOBAL_TOKENS = 435_000         # paper batch: 435k tokens
N_DEVICES = 64                  # paper: 64 GPUs on Web-50


def step_terms(cfg, hw: HwProfile, n: int):
    """(t_compute, t_a2a) per training step of the MoE enc-dec model."""
    toks = GLOBAL_TOKENS
    flops = 6 * cfg.n_active_params() * toks
    t_compute = flops / (n * hw.flops)
    # all-to-all: 2 bytes * d * tokens, x2 (dispatch+combine), x2 (fwd+bwd),
    # per MoE layer
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.moe.is_moe_layer(i))
    n_moe += sum(1 for i in range(cfg.encdec.n_encoder_layers)
                 if cfg.moe.is_moe_layer(i))
    a2a_bytes = 2 * cfg.d_model * toks * 2 * 2 * n_moe
    t_a2a = (a2a_bytes / n) / hw.link_bw
    return t_compute, t_a2a


def throughput(cfg, hw, gd: GatingDropoutConfig, n=N_DEVICES):
    t_c, t_a = step_terms(cfg, hw, n)
    t = (t_c * expected_expert_flop_fraction(gd)
         + t_a * expected_alltoall_fraction(gd))
    return GLOBAL_TOKENS / t


def measured_reduced(methods, *, steps: int, batch: int, seq: int = 16,
                     chunk: int = 8):
    """Measured steps/s per method: the scan-fused Trainer on the reduced
    CPU config (traced_cond, one executable per chunk length).

    History records carry the wall time of their enclosing chunk
    boundary, so (steps - chunk) / (t_last - t_first_boundary) measures
    every chunk after the first — compile time excluded."""
    out = {}
    for name, gd in methods.items():
        cfg = reduced(get_config("zcode-m3-base"))
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, gating_dropout=gd))
        tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=steps, seed=0)
        t0 = time.time()
        _, _, hist = run_trainer(cfg, tc, batch=batch, seq=seq, chunk=chunk,
                                 strategy="traced_cond")
        wall = time.time() - t0
        assert hist[0]["step"] < chunk <= tc.steps - chunk
        span = max(hist[-1]["time_s"] - hist[0]["time_s"], 1e-9)
        sps = (tc.steps - chunk) / span
        # keep tok_s on the same (compile-excluded) clock as steps_s
        tokens_per_step = hist[-1]["tok_s"] * hist[-1]["time_s"] / tc.steps
        tok_s = sps * tokens_per_step
        out[name] = {"steps_s": sps, "tok_s": tok_s,
                     "wall_s_incl_compile": wall}
        csv_row(f"table3/measured-reduced-cpu/{name}", 1e6 / sps,
                f"steps_s={sps:.2f};tok_s={tok_s:.0f}")
    return out


def main(fast: bool = True):
    cfg = get_config("zcode-m3-big")
    methods = {
        "baseline": GatingDropoutConfig(mode="off", rate=0.0),
        "gate_drop": GatingDropoutConfig(mode="gate_drop", rate=0.3),
        "gate_expert_drop": GatingDropoutConfig(mode="gate_expert_drop",
                                                rate=0.2),
    }
    paper = {"v100-100Gb-IB": {"baseline": 126e3, "gate_drop": 140e3,
                               "gate_expert_drop": 146e3},
             "a100-1.6Tb-IB": {"baseline": 362e3, "gate_drop": 372e3,
                               "gate_expert_drop": 384e3}}
    out = {}
    for hw in (V100_IB, A100_IB, TPU_V5E):
        out[hw.name] = {}
        base = throughput(cfg, hw, methods["baseline"])
        for m, gd in methods.items():
            tp = throughput(cfg, hw, gd)
            rel = (tp / base - 1) * 100
            p = paper.get(hw.name, {}).get(m)
            prel = ((p / paper[hw.name]["baseline"] - 1) * 100
                    if p else None)
            out[hw.name][m] = {"tok_s": tp, "rel_impr_pct": rel,
                               "paper_tok_s": p, "paper_rel_pct": prel}
            csv_row(f"table3/{hw.name}/{m}", 1e6 * GLOBAL_TOKENS / tp,
                    f"model_tok_s={tp:.0f};rel={rel:.1f}%"
                    + (f";paper_rel={prel:.1f}%" if prel is not None else ""))
    out["measured_reduced_cpu"] = measured_reduced(
        methods, steps=24 if fast else 48, batch=4 if fast else 8)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
