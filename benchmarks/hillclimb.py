import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbs on the three chosen (arch x shape) pairs.

Pairs (from the baseline roofline table):
  1. deepseek-v3-671b x train_4k  — most representative of the paper
     (largest all-to-all: baseline collective term 145 s/step).
  2. codeqwen1.5-7b x train_4k    — the collective-DOMINATED pair
     (TP activation all-reduces > memory term).
  3. hymba-1.5b x train_4k        — worst roofline fraction (0.8%),
     useful-FLOPs ratio 0.16.

Each ladder records hypothesis -> change -> before -> after -> verdict
into benchmarks/artifacts/perf_log.json (and markdown for EXPERIMENTS.md).

  PYTHONPATH=src python -m benchmarks.hillclimb [--pair N]
"""
import argparse
import json

from repro.configs.base import GatingDropoutConfig

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")

V5E = dict(flops=197e12, hbm=819e9, link=50e9)


def terms(rec, a2a_scale=1.0):
    t_c = rec["flops"] / V5E["flops"]
    t_m = rec["bytes_accessed"] / V5E["hbm"]
    wire = 0.0
    for kind, c in rec["collectives"].items():
        w = c.get("wire_bytes", 0.0)
        if kind == "all-to-all":
            w *= a2a_scale
        wire += w
    return {"compute": t_c, "memory": t_m, "collective": wire / V5E["link"]}


def run(arch, shape, *, overrides=None, tc_overrides=None, tag="hc",
        static_decision=None, a2a_scale=1.0):
    from repro.launch.dryrun import exact_costs
    rec = exact_costs(arch, shape, overrides=overrides, tag=tag,
                      tc_overrides=tc_overrides,
                      static_decision=static_decision, verbose=False)
    t = terms(rec, a2a_scale)
    t["dominant"] = max(("compute", "memory", "collective"), key=t.get)
    t["flops"] = rec["flops"]
    return t


def ladder(name, arch, shape, steps, log):
    print(f"\n=== hillclimb: {name} ({arch} x {shape}) ===")
    prev = None
    for label, hypothesis, kw in steps:
        t = run(arch, shape, tag=f"hc_{label}", **kw)
        entry = {"pair": name, "step": label, "hypothesis": hypothesis,
                 "compute_s": t["compute"], "memory_s": t["memory"],
                 "collective_s": t["collective"], "dominant": t["dominant"]}
        if prev is not None:
            for k in ("compute", "memory", "collective"):
                b, a = prev[k], t[k]
                entry[f"delta_{k}_pct"] = (a - b) / b * 100 if b else 0.0
            dom = prev["dominant"]
            entry["verdict"] = (
                "confirmed" if t[dom] < prev[dom] * 0.98 else
                "refuted" if t[dom] > prev[dom] * 1.02 else "neutral")
        log.append(entry)
        print(f"  [{label}] C={t['compute']:.3g}s M={t['memory']:.3g}s "
              f"X={t['collective']:.3g}s dom={t['dominant']}"
              + (f" verdict={entry.get('verdict','-')}" if prev else ""))
        prev = t


def pair1(log):
    """deepseek train: paper floor first, then beyond."""
    no_gd = GatingDropoutConfig(mode="off", rate=0.0)
    gd = GatingDropoutConfig(mode="gate_drop", rate=0.3,
                             strategy="host_cond")
    steps = [
        ("p0_no_gating_dropout",
         "paper-faithful MoE WITHOUT the paper's technique: full a2a every "
         "step — the floor the paper improves on",
         dict(overrides={"moe.gating_dropout": no_gd})),
        ("p1_gate_drop_p0.3",
         "PAPER: Gate-Drop p=0.3 skips the a2a on 30% of steps -> expected "
         "collective term x0.7 (napkin: a2a is ~all of the collective term)",
         dict(overrides={"moe.gating_dropout": gd}, a2a_scale=0.7)),
        ("p2_ep_on_model",
         "BEYOND: EP over data*model (256-way): per-device a2a bytes /16 "
         "and dispatch buffers /16 -> collective ~/16, memory down too",
         dict(overrides={"moe.gating_dropout": gd, "moe.ep_on_model": True},
              a2a_scale=0.7)),
        ("p3_bf16_params",
         "BEYOND: bf16 params halve param/grad HBM traffic and grad "
         "all-reduce bytes (memory term now dominant)",
         dict(overrides={"moe.gating_dropout": gd, "moe.ep_on_model": True,
                         "param_dtype": "bfloat16"}, a2a_scale=0.7)),
        ("p4_seq_parallel",
         "BEYOND: sequence-parallel activations shard the remat-saved "
         "tensors and their HBM traffic over `model`",
         dict(overrides={"moe.gating_dropout": gd, "moe.ep_on_model": True,
                         "param_dtype": "bfloat16", "seq_parallel": True},
              a2a_scale=0.7)),
    ]
    ladder("deepseek-train (paper->beyond)", "deepseek-v3-671b", "train_4k",
           steps, log)


def pair2(log):
    steps = [
        ("q0_baseline", "TP-16 dense train: activation all-reduces dominate "
         "(2/layer fwd + bwd)", dict()),
        ("q1_seq_parallel",
         "Megatron SP: all-reduce -> reduce-scatter + all-gather halves "
         "activation-collective wire bytes and shards saved activations",
         dict(overrides={"seq_parallel": True})),
        ("q2_bf16_params",
         "bf16 params: grad all-reduce + param HBM traffic halve",
         dict(overrides={"seq_parallel": True, "param_dtype": "bfloat16"})),
        ("q3_microbatch4",
         "4 microbatches: activation memory /4; collective per step "
         "unchanged (grads accumulated) -> memory term drops, collective "
         "flat (tests whether memory was activation-bound)",
         dict(overrides={"seq_parallel": True, "param_dtype": "bfloat16"},
              tc_overrides={"microbatches": 4})),
    ]
    ladder("codeqwen-train (collective-bound)", "codeqwen1.5-7b", "train_4k",
           steps, log)


def pair3(log):
    steps = [
        ("h0_baseline", "hymba train: useful-FLOPs 0.16 — masked-SWA waste, "
         "remat recompute, SSD intra-chunk overhead", dict()),
        ("h1_banded_swa",
         "banded SWA (block skipping): attention flops ~x(W+Cq)/L = "
         "~0.5x for L=4k, W=1k",
         dict(overrides={"banded_swa": True})),
        ("h2_no_remat",
         "1.1B params: activations fit without remat -> drop the ~1.33x "
         "recompute (compute term down ~25%)",
         dict(overrides={"banded_swa": True, "remat": False})),
        ("h3_ssd_chunk32",
         "SSD chunk 64->32: intra-chunk quadratic work per token halves "
         "(inter-chunk state flops grow slightly)",
         dict(overrides={"banded_swa": True, "remat": False,
                         "ssm.chunk": 32})),
    ]
    ladder("hymba-train (worst roofline frac)", "hymba-1.5b", "train_4k",
           steps, log)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=0, help="0=all, 1..3")
    args = ap.parse_args()
    log = []
    pairs = {1: pair1, 2: pair2, 3: pair3}
    for i, fn in pairs.items():
        if args.pair in (0, i):
            fn(log)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "perf_log.json")
    old = []
    if os.path.exists(path) and args.pair != 0:
        old = json.load(open(path))
        old = [e for e in old if not any(
            e["pair"] == n["pair"] for n in log)]
    with open(path, "w") as f:
        json.dump(old + log, f, indent=1)
    print(f"\nperf log -> {path}")


if __name__ == "__main__":
    main()
