"""Beyond paper — Table 7: scan-fused Trainer vs the legacy per-step loop.

Head-to-head on the reduced CPU zcode-m3-base config with gate_drop 0.3:

  legacy — the seed-era hot loop, faithfully: one jitted dispatch per
      step, per-step loop-based batch synthesis (sample_batch_loop), a
      host-side consensus draw per step, jnp conversion per step.
  fused  — the Trainer (DESIGN.md §8): lax.scan over --chunk steps in one
      executable (traced_cond: consensus bits precomputed in-graph),
      double-buffered prefetch over vectorized synthesis, metrics fetched
      at chunk boundaries only.

Both see the SAME decision stream ((seed, step) fold) and the SAME data
stream; final-loss parity is asserted. Writes
benchmarks/artifacts/table7_trainloop.json; acceptance bar: fused
steps/s >= 1.3x legacy on this config.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ART, csv_row
from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import drop_decision_host
from repro.data import MTTaskConfig, MultilingualMT
from repro.models import init_model
from repro.training import Trainer, init_train_state, make_train_step

import dataclasses

# Small per-step device work ON PURPOSE: the quantity under test is the
# HOST loop (per-step dispatch + eager consensus draw + input stalls),
# which is a fixed per-step cost. The reduced config keeps the zcode
# topology (enc-dec, MoE every other layer, gate_drop 0.3) but narrows
# the widths via reduced() overrides until the device step lands in the
# single-digit-ms range — the regime of a real accelerator, where this
# whole model's step is sub-millisecond. At full reduced width the CPU
# step is ~50ms on a 2-core container and the host loop (~6ms/step)
# vanishes in the noise: that shape measures this container's matmul
# throughput, not the loop under test.
BATCH, SEQ, CHUNK = 2, 10, 16


def _setup(steps: int):
    cfg = reduced(get_config("zcode-m3-base"), d_model=64, d_ff=128,
                  vocab=256, n_heads=2, n_kv_heads=2, head_dim=32)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, d_ff_expert=128,
        gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3)))
    tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=steps, seed=0)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8,
                                       max_len=SEQ, src_len=(4, 8)))
    return cfg, tc, task


def run_legacy(steps: int):
    """The seed-era loop: per-step dispatch, per-step host draw, per-step
    loop-based synthesis. Warm both executables first; timing covers the
    steady-state loop only."""
    cfg, tc, task = _setup(steps)
    gd = cfg.moe.gating_dropout
    step = make_train_step(cfg, tc)

    def batch(i):
        return {k: jnp.asarray(v)
                for k, v in task.sample_batch_loop(i, BATCH).items()
                if k != "lang"}

    state = init_train_state(init_model(jax.random.PRNGKey(tc.seed), cfg), tc)
    for dec in (False, True):     # compile both executables off the clock
        state, _ = step(state, batch(0), dec)   # donated: chain the states
    jax.block_until_ready(state)
    state = init_train_state(init_model(jax.random.PRNGKey(tc.seed), cfg), tc)
    t0 = time.perf_counter()
    m = None
    for i in range(steps):
        state, m = step(state, batch(i), drop_decision_host(gd, tc.seed, i))
    loss = float(m["loss"])       # final host sync, like the seed launcher
    wall = time.perf_counter() - t0
    return steps / wall, loss


def run_fused(steps: int):
    """The Trainer. jit caches are per-chunk_fn, so the warmup pass must
    reuse the same Trainer: measure steady-state chunks via the history's
    boundary timestamps (every chunk after the first)."""
    cfg, tc, task = _setup(steps)
    tr = Trainer(cfg, tc, task.train_batches(BATCH), chunk=CHUNK,
                 strategy="traced_cond", log=None, log_every=1)
    _, hist = tr.run()
    first_boundary = next(r for r in hist if r["step"] == CHUNK - 1)
    span = hist[-1]["time_s"] - first_boundary["time_s"]
    return (steps - CHUNK) / max(span, 1e-9), hist[-1]["loss"]


def main(fast: bool = True):
    steps = 48 if fast else 80
    assert steps % CHUNK == 0
    legacy_sps, legacy_loss = run_legacy(steps)
    fused_sps, fused_loss = run_fused(steps)
    speedup = fused_sps / legacy_sps
    # same decisions, same data: traced lax.cond vs the baked branch only
    # differ in kernel fusion (~1e-6/step), so after `steps` updates the
    # final losses must still agree to ~1e-3 relative. (Exact BITWISE
    # chunk parity is asserted separately in tests/test_trainer.py.)
    assert abs(fused_loss - legacy_loss) < 2e-3 * max(abs(legacy_loss), 1.0), \
        (fused_loss, legacy_loss)
    # the acceptance bar this table exists to hold (measured ~2x; 1.3 with
    # margin for machine noise)
    assert speedup >= 1.3, f"fused only {speedup:.2f}x over legacy"
    out = {
        "config": {"arch": "zcode-m3-base(reduced, d_model=64)",
                   "batch": BATCH, "seq": SEQ, "chunk": CHUNK,
                   "steps": steps, "gd": "gate_drop@0.3"},
        "legacy_steps_s": legacy_sps,
        "fused_steps_s": fused_sps,
        "speedup": speedup,
        "legacy_final_loss": legacy_loss,
        "fused_final_loss": fused_loss,
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "table7_trainloop.json"), "w") as f:
        json.dump(out, f, indent=1)
    csv_row("table7/legacy-per-step", 1e6 / legacy_sps,
            f"steps_s={legacy_sps:.2f}")
    csv_row("table7/fused-chunk", 1e6 / fused_sps,
            f"steps_s={fused_sps:.2f};speedup={speedup:.2f}x;"
            f"loss_parity={abs(fused_loss - legacy_loss):.2e}")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
