"""Serving example: batched prefill + compiled decode for any assigned arch.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v3-671b \
      --batch 4 --prompt-len 32   # reduced config, MLA absorbed decode

Demonstrates the per-family cache machinery (full KV, sliding-window ring
buffer, MLA compressed latents, SSM constant-size state) driven by the
one compiled generation loop in ``repro.serve`` (DESIGN.md §7).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import init_cache, init_model
from repro.serve import GenerateConfig, make_generate_fn


def describe_cache(caches):
    total = 0
    for leaf in jax.tree.leaves(caches):
        total += leaf.size * leaf.dtype.itemsize
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(
                key, (args.batch, 32), 3, cfg.vocab)

    caches = init_cache(cfg, args.batch, args.prompt_len + args.max_new)
    print(f"{cfg.arch_id} [{cfg.family}]  cache bytes: "
          f"{describe_cache(caches)/2**20:.1f} MiB")
    del caches

    fn = make_generate_fn(cfg, GenerateConfig(max_new=args.max_new,
                                              eos_id=-1))
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch))
    print(f"compile+first run: {time.time()-t0:.2f} s")
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch))
    dt = time.time() - t0
    print(f"decode: {dt/args.max_new*1e3:.1f} ms/token, "
          f"{args.batch*args.max_new/dt:.0f} tok/s (single compiled loop)")
    print("first sequence:", np.asarray(res.tokens)[0].tolist())


if __name__ == "__main__":
    main()
