"""Serving example: batched prefill + greedy decode for any assigned arch.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v3-671b \
      --batch 4 --prompt-len 32   # reduced config, MLA absorbed decode

Demonstrates the per-family cache machinery: full KV, sliding-window ring
buffer, MLA compressed latents, SSM constant-size state.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import decode_step, init_cache, init_model, prefill
from repro.training import make_serve_step


def describe_cache(caches):
    total = 0
    kinds = {}
    for leaf in jax.tree.leaves(caches):
        total += leaf.size * leaf.dtype.itemsize
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(
                key, (args.batch, 32), 3, cfg.vocab)

    max_seq = args.prompt_len + args.max_new
    t0 = time.time()
    logits, caches = prefill(params, batch, cfg, max_seq=max_seq)
    print(f"{cfg.arch_id} [{cfg.family}]  cache bytes: "
          f"{describe_cache(caches)/2**20:.1f} MiB "
          f"(prefill {time.time()-t0:.2f}s)")
    step = make_serve_step(cfg)
    cur = logits.argmax(-1).astype(jnp.int32)
    toks = []
    t0 = time.time()
    for i in range(args.max_new):
        logits, caches = step(params, caches, cur, args.prompt_len + i)
        cur = logits.argmax(-1).astype(jnp.int32)
        toks.append(np.asarray(cur)[:, 0])
    dt = time.time() - t0
    print(f"decode: {dt/args.max_new*1e3:.1f} ms/token, "
          f"{args.batch*args.max_new/dt:.0f} tok/s")
    print("first sequence:", np.stack(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
