"""Serving example: compiled one-shot decode AND continuous batching.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v3-671b \
      --batch 4 --prompt-len 32   # reduced config, MLA absorbed decode
  PYTHONPATH=src python examples/serve_decode.py --arch yi-6b \
      --continuous                # slot-pool scheduler over a mini trace

Demonstrates the per-family cache machinery (full KV, sliding-window ring
buffer, MLA compressed latents, SSM constant-size state) driven by the
one compiled generation loop in ``repro.serve`` (DESIGN.md §7), and the
continuous-batching scheduler over the same engine's slot-pool
primitives (DESIGN.md §9): mixed-length prompts with per-request token
budgets stream through a fixed pool of cache slots, freed slots are
re-prefilled mid-flight, and every request's tokens equal its one-shot
decode.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import init_cache, init_model
from repro.serve import (ContinuousScheduler, GenerateConfig, Request,
                         make_generate_fn)


def describe_cache(caches):
    total = 0
    for leaf in jax.tree.leaves(caches):
        total += leaf.size * leaf.dtype.itemsize
    return total


def one_shot(args, cfg, params, key):
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab)}
    if cfg.vlm is not None:
        batch["img_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_image_tokens, cfg.vlm.d_image))
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
        else:
            batch["enc_tokens"] = jax.random.randint(
                key, (args.batch, 32), 3, cfg.vocab)

    caches = init_cache(cfg, args.batch, args.prompt_len + args.max_new)
    print(f"{cfg.arch_id} [{cfg.family}]  cache bytes: "
          f"{describe_cache(caches)/2**20:.1f} MiB")
    del caches

    fn = make_generate_fn(cfg, GenerateConfig(max_new=args.max_new,
                                              eos_id=-1))
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch))
    print(f"compile+first run: {time.time()-t0:.2f} s")
    t0 = time.time()
    res = jax.block_until_ready(fn(params, batch))
    dt = time.time() - t0
    print(f"decode: {dt/args.max_new*1e3:.1f} ms/token, "
          f"{args.batch*args.max_new/dt:.0f} tok/s (single compiled loop)")
    print("first sequence:", np.asarray(res.tokens)[0].tolist())


def _request_extras(cfg, key):
    """Per-request conditioning inputs (no batch axis), per family."""
    extras = {}
    if cfg.vlm is not None:
        extras["img_embeds"] = np.asarray(jax.random.normal(
            key, (cfg.vlm.n_image_tokens, cfg.vlm.d_image)), np.float32)
    if cfg.encdec is not None:
        if cfg.encdec.frontend == "stub":
            extras["frames"] = np.asarray(jax.random.normal(
                key, (cfg.encdec.encoder_seq, cfg.d_model)), np.float32)
        else:
            extras["enc_tokens"] = np.asarray(jax.random.randint(
                key, (32,), 3, cfg.vocab), np.int32)
    return extras


def continuous(args, cfg, params, key):
    """Mini trace: 8 requests, mixed prompt lengths + budgets, 3 slots."""
    gen = GenerateConfig(max_new=args.max_new, eos_id=-1)
    reqs = []
    for i, (plen, budget) in enumerate(
            [(5, 6), (12, args.max_new), (8, 4), (15, 9),
             (6, 3), (10, args.max_new), (7, 5), (9, 8)]):
        toks = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 3, cfg.vocab), np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new=budget,
                            extras=_request_extras(
                                cfg, jax.random.fold_in(key, 100 + i)),
                            arrival=i * 0.01))
    sched = ContinuousScheduler(params, cfg, gen, n_slots=3,
                                prefill_buckets=(8, 16))
    t0 = time.time()
    results = sched.run(reqs)
    wall = time.time() - t0
    n_tok = sum(r.length for r in results)
    print(f"{cfg.arch_id}: served {len(results)} requests "
          f"({n_tok} tokens) through 3 slots in {wall:.2f} s")
    print(f"scheduler: {sched.stats}")
    for r in results[:3]:
        print(f"  request {r.rid}: {r.length} tokens, "
              f"ttft {r.ttft*1e3:.0f} ms -> {r.tokens.tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--continuous", action="store_true",
                    help="drive the continuous-batching scheduler over a "
                         "mini mixed-length trace (DESIGN.md §9)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_model(jax.random.fold_in(key, 0), cfg)
    if args.continuous:
        continuous(args, cfg, params, jax.random.fold_in(key, 1))
    else:
        one_shot(args, cfg, params, jax.random.fold_in(key, 1))


if __name__ == "__main__":
    main()
