"""Quickstart: build an MoE translation model with Gating Dropout, train it
a few steps on the synthetic multilingual task, and greedy-decode.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import drop_decision_host
from repro.data import MTTaskConfig, MultilingualMT
from repro.models import init_model
from repro.serve import GenerateConfig, generate
from repro.training import init_train_state, make_train_step

# 1. Config: the paper's Z-code-M3-base family at toy scale, with Gate-Drop
cfg = reduced(get_config("zcode-m3-base"))
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3)))
print(f"arch={cfg.arch_id}: {cfg.moe.n_experts} experts, "
      f"gating dropout p={cfg.moe.gating_dropout.rate}")

# 2. Data: deterministic synthetic multilingual MT
task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=4))

# 3. Train with the paper's host_cond strategy: per-step consensus bit via
#    the shared (seed, step) PRNG — the dropped executable has NO all-to-all
tc = TrainConfig(lr=2e-3, warmup_steps=20, steps=100, seed=0)
state = init_train_state(init_model(jax.random.PRNGKey(0), cfg), tc)
step = make_train_step(cfg, tc)
for i in range(100):
    batch = {k: jnp.asarray(v) for k, v in task.sample_batch(i, 16).items()
             if k != "lang"}
    dropped = drop_decision_host(cfg.moe.gating_dropout, tc.seed, i)
    state, m = step(state, batch, dropped)
    if i % 20 == 0 or i == 99:
        print(f"step {i:3d} loss={float(m['loss']):.3f} "
              f"acc={float(m['acc']):.3f} dropped={dropped}")

# 4. Greedy decode one source sentence through the compiled engine
#    (repro.serve, DESIGN.md §7: prefill + decode loop in one executable)
val = task.sample_batch(9999, 1)
batch = {"enc_tokens": jnp.asarray(val["enc_tokens"]),
         "tokens": jnp.asarray(val["tokens"][:, :1])}
res = generate(state["params"], batch, cfg, GenerateConfig(max_new=20))
print("source :", val["enc_tokens"][0][:12].tolist())
print("ref    :", val["labels"][0][:12].tolist())
print("decoded:", res.tokens[0][:12].tolist())
