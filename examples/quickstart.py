"""Quickstart: build an MoE translation model with Gating Dropout, train it
a few steps on the synthetic multilingual task, and greedy-decode.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.data import MTTaskConfig, MultilingualMT
from repro.serve import GenerateConfig, generate
from repro.training import Trainer

# 1. Config: the paper's Z-code-M3-base family at toy scale, with Gate-Drop
cfg = reduced(get_config("zcode-m3-base"))
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, gating_dropout=GatingDropoutConfig(mode="gate_drop", rate=0.3)))
print(f"arch={cfg.arch_id}: {cfg.moe.n_experts} experts, "
      f"gating dropout p={cfg.moe.gating_dropout.rate}")

# 2. Data: deterministic synthetic multilingual MT
task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=4))

# 3. Train through the scan-fused Trainer (DESIGN.md §8): 10 steps per
#    compiled dispatch, consensus bits precomputed in-graph from the shared
#    (seed, step) PRNG, batches prefetched on a background thread.
#    (`python -m repro.launch.train --strategy host_cond` runs the
#    paper-faithful two-executable dispatch instead.)
tc = TrainConfig(lr=2e-3, warmup_steps=20, steps=100, seed=0)
trainer = Trainer(cfg, tc, task.train_batches(16),
                  chunk=10, strategy="traced_cond", log_every=20)
state, history = trainer.run()

# 4. Greedy decode one source sentence through the compiled engine
#    (repro.serve, DESIGN.md §7: prefill + decode loop in one executable)
val = task.sample_batch(9999, 1)
batch = {"enc_tokens": jnp.asarray(val["enc_tokens"]),
         "tokens": jnp.asarray(val["tokens"][:, :1])}
res = generate(state["params"], batch, cfg, GenerateConfig(max_new=20))
print("source :", val["enc_tokens"][0][:12].tolist())
print("ref    :", val["labels"][0][:12].tolist())
print("decoded:", res.tokens[0][:12].tolist())
