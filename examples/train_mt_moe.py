"""End-to-end driver: train a ~100M-param MoE translation model for a few
hundred steps comparing baseline vs Gate-Drop, with eval BLEU + checkpoints.

This is the paper's Table-2 experiment at CPU-tractable scale.

  PYTHONPATH=src python examples/train_mt_moe.py [--steps 300] [--big]

--big uses a ~100M-parameter model (slower per step on CPU); the default is
a ~20M model so the example finishes quickly.
"""
import argparse
import dataclasses
import json
import time

import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import drop_decisions_host
from repro.data import MTTaskConfig, MultilingualMT
from repro.launch.train import greedy_bleu
from repro.training import Trainer, make_eval_step


def build_cfg(big: bool, gd_mode: str, gd_rate: float):
    cfg = get_config("zcode-m3-base")
    if big:   # ~100M params
        cfg = reduced(cfg, n_layers=4, d_model=512, d_ff=1024, vocab=8192,
                      n_heads=8, n_kv_heads=8, head_dim=64, max_seq=64)
        moe = dataclasses.replace(cfg.moe, n_experts=8, d_ff_expert=1024)
    else:     # ~20M params
        cfg = reduced(cfg, vocab=2048)
        moe = cfg.moe
    moe = dataclasses.replace(moe, gating_dropout=GatingDropoutConfig(
        mode=gd_mode, rate=gd_rate))
    return dataclasses.replace(cfg, moe=moe)


def run(name, cfg, steps, batch, seed=0, ckpt=None):
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 20), steps=steps,
                     seed=seed)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8))
    gd = cfg.moe.gating_dropout
    t0 = time.time()
    # train through the scan-fused Trainer (DESIGN.md §8); checkpointing
    # and logging are the Trainer's job now
    trainer = Trainer(
        cfg, tc, task.train_batches(batch),
        chunk=10, strategy="traced_cond", ckpt_dir=ckpt,
        ckpt_meta={"method": name},
        log_every=max(steps // 10, 1),
        log=lambda s: print(f"[{name}] {s}"))
    state, history = trainer.run()
    wall = time.time() - t0
    n_drop = int(drop_decisions_host(gd, seed, 0, steps).sum())
    ev = make_eval_step(cfg)
    vb = {k: jnp.asarray(v) for k, v in task.sample_batch(10_000, 64).items()
          if k != "lang"}
    em = ev(state["params"], vb)
    bleu = greedy_bleu(state["params"], cfg, task)
    res = {"method": name, "val_loss": float(em["loss"]),
           "val_acc": float(em["acc"]), "bleu_proxy": bleu,
           "wall_s": wall, "dropped_steps": n_drop,
           "tok_s": history[-1]["tok_s"]}
    print(f"[{name}] {json.dumps(res)}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    results = [
        run("baseline", build_cfg(args.big, "off", 0.0), args.steps,
            args.batch),
        run("gate_drop_p0.3", build_cfg(args.big, "gate_drop", 0.3),
            args.steps, args.batch,
            ckpt=args.ckpt_dir),
        run("gate_expert_drop_p0.2",
            build_cfg(args.big, "gate_expert_drop", 0.2), args.steps,
            args.batch),
    ]
    base = results[0]
    print("\n== summary (vs baseline) ==")
    for r in results:
        print(f"{r['method']:24s} bleu={r['bleu_proxy']:6.2f} "
              f"({r['bleu_proxy']-base['bleu_proxy']:+.2f}) "
              f"val_acc={r['val_acc']:.3f} dropped={r['dropped_steps']}")


if __name__ == "__main__":
    main()
