"""Figure-6 style sweep: dropout rate p vs quality + expected comm savings.

  PYTHONPATH=src python examples/dropout_rate_sweep.py [--steps 120]
"""
import argparse
import dataclasses
import json

import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import GatingDropoutConfig, TrainConfig
from repro.core.gating_dropout import expected_alltoall_fraction
from repro.data import MTTaskConfig, MultilingualMT
from repro.training import Trainer, make_eval_step


def run(rate, mode, steps, batch, seed=0):
    cfg = reduced(get_config("zcode-m3-base"))
    gd = GatingDropoutConfig(mode=mode if rate > 0 else "off", rate=rate)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, gating_dropout=gd))
    tc = TrainConfig(lr=2e-3, warmup_steps=max(steps // 10, 10), steps=steps,
                     seed=seed)
    task = MultilingualMT(MTTaskConfig(vocab=cfg.vocab, n_langs=8))
    trainer = Trainer(cfg, tc, task.train_batches(batch),
                      chunk=10, strategy="traced_cond", log=None)
    state, _ = trainer.run()
    ev = make_eval_step(cfg)
    vb = {k: jnp.asarray(v) for k, v in task.sample_batch(10_000, 64).items()
          if k != "lang"}
    return float(ev(state["params"], vb)["acc"]), gd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--mode", default="gate_expert_drop")
    args = ap.parse_args()
    base = None
    for p in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]:
        acc, gd = run(p, args.mode, args.steps, args.batch)
        if base is None:
            base = acc
        a2a = expected_alltoall_fraction(gd)
        print(json.dumps({"p": p, "val_acc": round(acc, 4),
                          "delta_vs_baseline": round(acc - base, 4),
                          "alltoall_fraction": a2a}))


if __name__ == "__main__":
    main()
